"""Shared benchmark harness utilities."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compression, fedavg


def run_fed(loss_fn, params0, batches, comp, cfg, *, rounds, mask=None,
            sigma0=0.0, plateau=None, eval_fn=None, dynamic_sigma=False,
            fetch_every=16, agg_backend=None, ctx=None):
    """Run ``rounds`` federated rounds; returns dict of metric curves.

    ``batches``: callable round_idx -> batch pytree (groups, n, E, ...).

    ``ctx`` is the RoundContext the step runs under (core/context.py); when
    omitted it is built from the legacy ``dynamic_sigma`` / ``agg_backend``
    kwargs with donation on. Per ``ctx.donate_state`` the server state is
    DONATED into the jitted round step (params, opt state, and the
    (G, N, n_coords) residual buffers update in place instead of being
    copied every round), and per-round ``RoundMetrics`` stay on device,
    fetched in batches of ``fetch_every`` rounds so the host never blocks
    the device between steps. Plateau mode keeps the per-round fetch — the
    controller genuinely needs each round's scalar loss before the next
    sigma.
    """
    if ctx is None:
        ctx = fedavg.RoundContext(agg_backend=agg_backend,
                                  dynamic_sigma=dynamic_sigma)
    elif agg_backend is not None or dynamic_sigma:
        raise ValueError("pass ctx OR the legacy agg_backend/dynamic_sigma "
                         "kwargs, not both")
    step = jax.jit(fedavg.build_round_step(loss_fn, comp, cfg, ctx),
                   donate_argnums=(0,) if ctx.donate_state else ())
    # copy params0 so donation never consumes caller-owned buffers
    state = fedavg.init_server_state(jax.tree.map(jnp.array, params0), cfg,
                                     comp, jax.random.PRNGKey(1), sigma0)
    if mask is None:
        mask = jnp.ones((cfg.client_groups, cfg.n_clients))
    losses, bits, evals, sigmas = [], [], [], []
    total_bits = 0.0
    per_round_fetch = plateau is not None
    pending = []   # (loss, uplink_bits) device scalars awaiting one fetch

    def drain():
        nonlocal total_bits
        # sigma is constant off the plateau path, so the current state's
        # value stands in for every pending round exactly.
        sig = float(state.sigma)
        for lv, bv in jax.device_get(pending):
            losses.append(float(lv))
            total_bits += float(bv)
            bits.append(total_bits)
            sigmas.append(sig)
        pending.clear()

    for t in range(rounds):
        state, m = step(state, batches(t), mask)
        if per_round_fetch:
            losses.append(float(m.loss))
            total_bits += float(m.uplink_bits)
            bits.append(total_bits)
            sigmas.append(float(state.sigma))
            state = state._replace(
                sigma=jnp.asarray(plateau.update(losses[-1]), jnp.float32))
        else:
            pending.append((m.loss, m.uplink_bits))
            if len(pending) >= fetch_every or t == rounds - 1:
                drain()
        if eval_fn is not None and (t % max(1, rounds // 20) == 0
                                    or t == rounds - 1):
            evals.append((t, float(eval_fn(state.params))))
    return {"loss": losses, "bits": bits, "evals": evals, "sigmas": sigmas,
            "params": state.params}


def timeit(fn, *args, iters=20, warmup=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


from repro.models.mlp import mlp_loss_builder  # noqa: F401,E402
