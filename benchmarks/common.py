"""Shared benchmark harness utilities."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compression, fedavg


def run_fed(loss_fn, params0, batches, comp, cfg, *, rounds, mask=None,
            sigma0=0.0, plateau=None, eval_fn=None, dynamic_sigma=False):
    """Run ``rounds`` federated rounds; returns dict of metric curves.

    ``batches``: callable round_idx -> batch pytree (groups, n, E, ...).
    """
    step = jax.jit(fedavg.build_round_step(loss_fn, comp, cfg,
                                           dynamic_sigma=dynamic_sigma))
    state = fedavg.init_server_state(params0, cfg, comp, jax.random.PRNGKey(1),
                                     sigma0)
    if mask is None:
        mask = jnp.ones((cfg.client_groups, cfg.n_clients))
    losses, bits, evals, sigmas = [], [], [], []
    total_bits = 0.0
    for t in range(rounds):
        state, m = step(state, batches(t), mask)
        losses.append(float(m.loss))
        total_bits += float(m.uplink_bits)
        bits.append(total_bits)
        sigmas.append(float(state.sigma))
        if plateau is not None:
            state = state._replace(
                sigma=jnp.asarray(plateau.update(float(m.loss)), jnp.float32))
        if eval_fn is not None and (t % max(1, rounds // 20) == 0
                                    or t == rounds - 1):
            evals.append((t, float(eval_fn(state.params))))
    return {"loss": losses, "bits": bits, "evals": evals, "sigmas": sigmas,
            "params": state.params}


def timeit(fn, *args, iters=20, warmup=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


from repro.models.mlp import mlp_loss_builder  # noqa: F401,E402
