"""Benchmark harness: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--fast] [--json]
                                            [--devices D]

Prints ``name,metric,value`` CSV rows. ``--json`` additionally writes the
perf-trajectory files every later perf PR is compared against:
``BENCH_round.json`` (fed_round_step) and ``BENCH_kernels.json``
(kernel_throughput). Mapping to the paper:

  fig1_consensus_dims    Fig. 1  consensus, algorithms x problem dimension
  fig2_noise_scales      Fig. 2  z-SignSGD under various noise scales
  fig3_noniid            Fig. 3  algorithms on extreme non-iid classification
  fig5_local_steps       Fig. 5  FedAvg vs 1-SignFedAvg, E sweep
  fig6_plateau           Fig. 6  Plateau criterion vs fixed/optimal sigma
  fig16_qsgd             Fig. 16 1-Sign vs QSGD/FedPAQ bits-to-accuracy
  fig17_dp               Fig. 17 DP-SignFedAvg vs DP-FedAvg across eps
  table2_bits            Table 2 uplink bits per round per algorithm
  kernel_throughput      compression kernel us/call + bytes moved
  client_encode          client encode: dense draw vs counter-based fused,
                         per backend and per z (rows in BENCH_kernels.json)
  fed_round_step         full jitted round + server aggregation wall-clock,
                         legacy dense round (dense noise draw + dense
                         sign-matrix aggregation) vs fully-fused
  cohort_round           streaming massive-cohort round: n=1k/10k clients
                         shard-scanned in O(shard*d/8) wire memory, with XLA
                         peak-temp estimates; with --devices D also the
                         shard_map multi-device rows + scaling efficiency
                         (rows in BENCH_round.json)
  robust_agg             Byzantine-robust agg modes (vote/trimmed/median)
                         vs the mean popcount round at n=32, ~1.3M coords,
                         plus one adversarial round (--robust-agg shorthand;
                         rows in BENCH_round.json)
  cv_round               compressed-SCAFFOLD control variates
                         (cv|zsign_packed) vs plain zsign_packed at n=32,
                         ~1.3M coords — the <=1.3x overhead acceptance row
                         (--cv shorthand; rows in BENCH_round.json)
  async_round            async deadline rounds vs the sync straggler
                         barrier: simulated p50/p90 round close times under
                         heavy-tail latency + measured zero-latency driver
                         overhead (--async shorthand; rows in
                         BENCH_round.json)

``--devices D`` forces D host devices (threads) so the ``stream(devices=D)``
rows run without real hardware. It must take effect before jax initializes
its backend, hence the pre-import argv peek below.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _apply_devices_flag() -> int:
    ap = argparse.ArgumentParser(add_help=False)
    ap.add_argument("--devices", type=int, default=0)
    ns, _ = ap.parse_known_args()
    if ns.devices > 1:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={ns.devices}"
        ).strip()
    return ns.devices


_DEVICES = _apply_devices_flag()

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compression, fedavg
from repro.core.dp import calibrate_noise
from repro.core.plateau import PlateauController
from repro.data import synthetic
from benchmarks.common import mlp_loss_builder, run_fed, timeit
from repro.core.noise import eta_z

ROWS = []


def sign_slr(target: float, z: int, sigma: float, gamma: float) -> float:
    """Server lr such that the effective per-coordinate sign step is
    ``target`` (decode multiplies by eta_z*sigma; engine by gamma)."""
    scale = eta_z(z) * sigma if sigma > 0 else 1.0
    return target / (scale * gamma)


def emit(name, metric, value):
    ROWS.append((name, metric, value))
    print(f"{name},{metric},{value}")


# ---------------------------------------------------------------------------

def fig1_consensus_dims(fast=False):
    """Consensus problem, distance-to-opt after fixed rounds vs dimension."""
    dims = [10, 100] if fast else [10, 100, 1000]
    rounds = 300 if fast else 1500
    n = 10
    algos = {
        "GD": (compression.Pipeline("identity"), 100.0),
        "SignSGD": (compression.Pipeline("zsign(sigma=0.0)"),
                    sign_slr(0.01, 1, 0.0, 0.01)),
        "1-SignSGD": (compression.Pipeline("zsign(z=1,sigma=2.0)"),
                      sign_slr(0.01, 1, 2.0, 0.01)),
        "inf-SignSGD": (compression.Pipeline("zsign(z=0,sigma=2.0)"),
                        sign_slr(0.01, 0, 2.0, 0.01)),
        "Sto-SignSGD": (compression.Pipeline("stosign"),
                        sign_slr(0.01, 1, 0.0, 0.01)),
    }
    for d in dims:
        y = jax.random.normal(jax.random.PRNGKey(0), (1, n, d))
        opt = y[0].mean(0)
        loss_fn = lambda p, b: 0.5 * jnp.sum((p["x"] - b["y"]) ** 2)
        for name, (comp, slr) in algos.items():
            cfg = fedavg.FedConfig(n_clients=n, client_lr=0.01, server_lr=slr)
            out = run_fed(loss_fn, {"x": jnp.zeros(d)},
                          lambda t: {"y": y[:, :, None]}, comp, cfg,
                          rounds=rounds)
            dist = float(jnp.linalg.norm(out["params"]["x"] - opt))
            emit("fig1_consensus_dims", f"{name}_d{d}_dist", round(dist, 4))


def fig2_noise_scales(fast=False):
    d, n = 100, 10
    rounds = 300 if fast else 1500
    y = jax.random.normal(jax.random.PRNGKey(0), (1, n, d))
    opt = y[0].mean(0)
    loss_fn = lambda p, b: 0.5 * jnp.sum((p["x"] - b["y"]) ** 2)
    for z, zname in [(1, "1"), (0, "inf")]:
        for sigma in [0.1, 0.5, 2.0, 10.0]:
            comp = compression.Pipeline(f"zsign(z={z},sigma={sigma})")
            cfg = fedavg.FedConfig(n_clients=n, client_lr=0.01, server_lr=0.05)
            out = run_fed(loss_fn, {"x": jnp.zeros(d)},
                          lambda t: {"y": y[:, :, None]}, comp, cfg,
                          rounds=rounds)
            dist = float(jnp.linalg.norm(out["params"]["x"] - opt))
            emit("fig2_noise_scales", f"z{zname}_sigma{sigma}_dist",
                 round(dist, 4))


def _noniid_task(n_clients=10, E=1, micro=32, partition="label", alpha=1.0):
    x, y = synthetic.gaussian_mixture_task(n_classes=10, dim=64,
                                           n_per_class=200)
    if partition == "label":
        parts = synthetic.label_partition(y, n_clients)
    else:
        parts = synthetic.dirichlet_partition(y, n_clients, alpha=alpha)
    init, loss_fn, acc_fn = mlp_loss_builder(64, 10)

    def batches(t):
        return synthetic.client_batches(x, y, parts, (1, n_clients, E, micro),
                                        seed=1, round_idx=t)

    return init, loss_fn, acc_fn, batches, (x, y)


def fig3_noniid(fast=False):
    """Extreme non-iid (one label per client), test accuracy."""
    rounds = 60 if fast else 400
    init, loss_fn, acc_fn, batches, (x, y) = _noniid_task()
    algos = {
        "SGDwM": ("identity", dict(server_opt="momentum",
                                   server_opt_kw=(("beta", 0.9),),
                                   server_lr=0.05)),
        "SignSGD": ("zsign(sigma=0.0)",
                    dict(server_lr=sign_slr(0.01, 1, 0.0, 0.05))),
        "EF-SignSGDwM": ("ef|zsign", dict(server_opt="momentum",
                                          server_opt_kw=(("beta", 0.9),),
                                          server_lr=0.05)),
        "Sto-SignSGDwM": ("stosign", dict(
            server_opt="momentum", server_opt_kw=(("beta", 0.9),),
            server_lr=sign_slr(0.005, 1, 0.0, 0.05))),
        "1-SignSGD": ("zsign(z=1,sigma=0.05)",
                      dict(server_lr=sign_slr(0.01, 1, 0.05, 0.05))),
        "inf-SignSGD": ("zsign(z=0,sigma=0.05)",
                        dict(server_lr=sign_slr(0.01, 0, 0.05, 0.05))),
    }
    for name, (spec, fkw) in algos.items():
        comp = compression.Pipeline(spec)
        cfg = fedavg.FedConfig(n_clients=10, client_lr=0.05, **fkw)
        out = run_fed(loss_fn, init(jax.random.PRNGKey(0)), batches, comp, cfg,
                      rounds=rounds, eval_fn=lambda p: acc_fn(p, x, y))
        emit("fig3_noniid", f"{name}_acc", round(out["evals"][-1][1], 4))
        emit("fig3_noniid", f"{name}_Mbits",
             round(out["bits"][-1] / 1e6, 2))


def fig5_local_steps(fast=False):
    """FedAvg-style benefit of E local steps (Dirichlet non-iid)."""
    rounds = 40 if fast else 200
    for E in [1, 2, 4, 8]:
        init, loss_fn, acc_fn, batches, (x, y) = _noniid_task(
            E=E, micro=16, partition="dirichlet")
        for name, spec in [("FedAvg", "identity"),
                           ("1-SignFedAvg", "zsign(z=1,sigma=0.01)")]:
            comp = compression.Pipeline(spec)
            slr = (0.5 if spec == "identity"
                   else sign_slr(0.01, 1, 0.01, 0.05))
            cfg = fedavg.FedConfig(n_clients=10, local_steps=E,
                                   client_lr=0.05, server_lr=slr)
            out = run_fed(loss_fn, init(jax.random.PRNGKey(0)), batches, comp,
                          cfg, rounds=rounds,
                          eval_fn=lambda p: acc_fn(p, x, y))
            emit("fig5_local_steps", f"{name}_E{E}_acc",
                 round(out["evals"][-1][1], 4))


def fig6_plateau(fast=False):
    """Plateau criterion vs fixed sigma on the non-iid task."""
    rounds = 60 if fast else 400
    init, loss_fn, acc_fn, batches, (x, y) = _noniid_task()
    comp = compression.Pipeline("zsign(z=1,sigma=0.05)")
    cfg = fedavg.FedConfig(n_clients=10, client_lr=0.05,
                           server_lr=sign_slr(0.01, 1, 0.05, 0.05))
    out_fix = run_fed(loss_fn, init(jax.random.PRNGKey(0)), batches, comp, cfg,
                      rounds=rounds, eval_fn=lambda p: acc_fn(p, x, y))
    emit("fig6_plateau", "fixed_sigma_acc", round(out_fix["evals"][-1][1], 4))

    plateau = PlateauController(sigma_init=0.005, sigma_bound=0.5, kappa=10,
                                beta=1.5)
    out_pl = run_fed(loss_fn, init(jax.random.PRNGKey(0)), batches, comp, cfg,
                     rounds=rounds, sigma0=0.005, plateau=plateau,
                     eval_fn=lambda p: acc_fn(p, x, y), dynamic_sigma=True)
    emit("fig6_plateau", "plateau_acc", round(out_pl["evals"][-1][1], 4))
    emit("fig6_plateau", "plateau_final_sigma", round(out_pl["sigmas"][-1], 4))


def fig16_qsgd(fast=False):
    """1-SignSGD vs QSGD at matched uplink budget."""
    rounds = 60 if fast else 300
    init, loss_fn, acc_fn, batches, (x, y) = _noniid_task()
    cases = [("1-SignSGD", "zsign(z=1,sigma=0.05)",
              sign_slr(0.01, 1, 0.05, 0.05)),
             ("QSGD_s1", "qsgd(s=1)", 1.0),
             ("QSGD_s4", "qsgd(s=4)", 1.0)]
    for name, spec, slr in cases:
        comp = compression.Pipeline(spec)
        cfg = fedavg.FedConfig(n_clients=10, client_lr=0.05, server_lr=slr)
        out = run_fed(loss_fn, init(jax.random.PRNGKey(0)), batches, comp, cfg,
                      rounds=rounds, eval_fn=lambda p: acc_fn(p, x, y))
        emit("fig16_qsgd", f"{name}_acc", round(out["evals"][-1][1], 4))
        emit("fig16_qsgd", f"{name}_Mbits", round(out["bits"][-1] / 1e6, 2))


def fig17_dp(fast=False):
    """DP-SignFedAvg vs uncompressed DP-FedAvg across privacy budgets."""
    rounds = 50 if fast else 250
    init, loss_fn, acc_fn, batches, (x, y) = _noniid_task(
        partition="dirichlet")
    C = 0.5
    q = 0.3  # client subsampling (privacy amplification, paper App. F)
    for eps in ([2.0, 8.0] if fast else [1.0, 2.0, 4.0, 8.0]):
        nm = calibrate_noise(q=q, steps=rounds, target_eps=eps, delta=1e-3,
                             hi=200.0)
        for name, spec, slr in [
                ("DP-SignFedAvg", f"zsign(z=1,sigma={nm * C})",
                 sign_slr(0.01, 1, nm * C, 0.05)),
                ("DP-FedAvg", f"dp(noise={nm * C})|dense", 1.0)]:
            comp = compression.Pipeline(spec)
            cfg = fedavg.FedConfig(n_clients=10, client_lr=0.05,
                                   server_lr=slr, dp_clip=C)
            mask = jnp.zeros((1, 10)).at[0, :3].set(1.0)  # q = 0.3
            out = run_fed(loss_fn, init(jax.random.PRNGKey(0)), batches, comp,
                          cfg, rounds=rounds, mask=mask,
                          eval_fn=lambda p: acc_fn(p, x, y))
            emit("fig17_dp", f"{name}_eps{eps}_acc",
                 round(out["evals"][-1][1], 4))


def table2_bits(fast=False):
    d = 1_000_000
    for name, spec in [
            ("uncompressed_32bit", "identity"),
            ("EF-SignSGD", "ef|zsign"),
            ("Sto-SignSGD", "stosign"),
            ("1-SignFedAvg", "zsign(z=1,sigma=0.01)"),
            ("inf-SignFedAvg", "zsign(z=0,sigma=0.01)"),
            ("1-SignFedAvg_pallas", "zsign_packed(z=1,sigma=0.01)"),
            ("QSGD_s1", "qsgd(s=1)"),
            ("TopK_1pct", "ef|topk(frac=0.01)")]:
        wf = compression.Pipeline(spec).wire_format()
        emit("table2_bits", f"{name}_bits_per_round_per_client",
             int(d * wf.bits_per_coord))
        emit("table2_bits", f"{name}_wire", f"{wf.layout}/{wf.dtype}")


def _time_donated_rounds(step, state, batch, mask, iters, warmup, reps=3):
    """Time a donated round step by threading the state through (the donated
    input is consumed each call, so the loop must carry it). Reports the
    BEST of ``reps`` timed windows — the standard robust timer on a small
    shared box, where a background burst inside any single window would
    otherwise dominate the mean."""
    for _ in range(warmup):
        state, m = step(state, batch, mask)
    jax.block_until_ready((state, m))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(iters):
            state, m = step(state, batch, mask)
        jax.block_until_ready((state, m))
        best = min(best, (time.perf_counter() - t0) / iters * 1e6)
    return best


def fed_round_step(fast=False):
    """Wall-clock of one jitted federated round (realistic MLP, n_clients
    sweep): the fully-dense legacy round (dense noise draw on the client +
    dense-sign-matrix aggregation on the server) vs the fully-fused round
    (counter-based fused encode + fused sign-reduce), plus the isolated
    server-aggregation step on the same payload shapes and a
    client_groups > 1 pair exercising the compressed-domain group scan.
    This is the perf baseline later PRs are compared against."""
    from repro.core import wire
    # width 1024 (~1.3M coords, PR 3; PR 2 ran width 512 / 0.4M): at 512 the
    # per-client matmuls are too small to use even a 2-core box, so engine
    # overheads — identical on both paths — drowned the compression terms
    # this benchmark exists to compare.
    dim, classes, width = 256, 10, (128 if fast else 1024)
    init, loss_fn, _ = mlp_loss_builder(dim, classes, width=width)
    params = init(jax.random.PRNGKey(0))
    d = sum(p.size for p in jax.tree_util.tree_leaves(params))
    emit("fed_round_step", "model_coords", d)
    micro = 8
    iters, warmup = (3, 1) if fast else (5, 2)

    def time_round(n, groups, agg, enc, mask_flag=False, legacy=False,
                   spec=None):
        cfg = fedavg.FedConfig(n_clients=n, client_groups=groups,
                               client_lr=0.05,
                               server_lr=sign_slr(0.01, 1, 0.05, 0.05))
        kx, ky = jax.random.split(jax.random.PRNGKey(2))
        batch = {"x": jax.random.normal(kx, (groups, n, 1, micro, dim)),
                 "y": jax.random.randint(ky, (groups, n, 1, micro), 0,
                                         classes)}
        mask = jnp.ones((groups, n))
        comp = (compression.Pipeline(spec) if spec else
                compression.ZSignCompressor(z=1, sigma=0.05))
        ctx = fedavg.RoundContext(agg_backend=agg, encode_backend=enc,
                                  weights_are_mask=mask_flag,
                                  legacy_client_path=legacy)
        step = jax.jit(fedavg.build_round_step(loss_fn, comp, cfg, ctx),
                       donate_argnums=0)
        # fresh param copies: the donated step consumes its state buffers
        state = fedavg.init_server_state(
            jax.tree.map(jnp.array, params), cfg, comp, jax.random.PRNGKey(1))
        return _time_donated_rounds(step, state, batch, mask, iters, warmup)

    # "dense" measures the full pre-PR3 round (dense noise draw + dense
    # sign-matrix aggregation + legacy client step); "fused" is the current
    # default path, so the speedup is the real round-over-round delta.
    for n in ([8, 32] if fast else [8, 32, 64]):
        times = {}
        for label, (agg, enc) in [("dense", ("dense", "reference")),
                                  ("fused", ("auto", "auto"))]:
            times[label] = time_round(n, 1, agg, enc, legacy=(label == "dense"))
            emit("fed_round_step", f"round_{label}_us_n{n}",
                 round(times[label], 1))
        emit("fed_round_step", f"round_speedup_n{n}",
             round(times["dense"] / times["fused"], 2))
        if n == 32:
            t_mask = time_round(n, 1, "auto", "auto", mask_flag=True)
            emit("fed_round_step", "round_fused_mask_us_n32",
                 round(t_mask, 1))
            # pipeline-spec rows: the staged API builds the same round as
            # the legacy kwargs path, so these must land within noise of
            # round_fused_us_n32 (redesign is perf-neutral), and the fused
            # dp|sign composition must not reopen a dense client surface.
            t_spec = time_round(n, 1, "auto", "auto",
                                spec="zsign(z=1,sigma=0.05)")
            emit("fed_round_step", "round_pipeline_us_n32", round(t_spec, 1))
            emit("fed_round_step", "round_speedup_pipeline_n32",
                 round(times["dense"] / t_spec, 2))
            t_dp = time_round(n, 1, "auto", "auto",
                              spec="dp(clip=1.0,noise=0.05)|zsign")
            emit("fed_round_step", "round_pipeline_dp_us_n32",
                 round(t_dp, 1))

        # isolated server aggregation on the same wire shapes: the term the
        # fused agg backend actually changes (the local-SGD compute above is
        # backend-invariant).
        nb = -(-d // 8)
        payload = jax.random.randint(jax.random.PRNGKey(3), (n, nb), 0, 256,
                                     jnp.int32).astype(jnp.uint8)
        live = jnp.ones((n,), jnp.float32)
        aggf = {"dense": jax.jit(wire.unpack_sum_dense),
                "fused": jax.jit(wire.unpack_sum)}
        aus = {k: timeit(f, payload, live, iters=max(iters, 10),
                         warmup=warmup + 2) for k, f in aggf.items()}
        for k, v in aus.items():
            emit("fed_round_step", f"agg_{k}_us_n{n}", round(v, 1))
        emit("fed_round_step", f"agg_speedup_n{n}",
             round(aus["dense"] / aus["fused"], 2))

    # sequential client groups: the scan now stacks wire payloads and the
    # server reduces the (G*N, n_bytes) stack once (cross-group working set
    # 1 bit/coord) vs the legacy dense draw + dense per-group aggregation.
    g, n = (2, 8) if fast else (4, 8)
    tg = {}
    for label, (agg, enc) in [("dense", ("dense", "reference")),
                              ("fused", ("auto", "auto"))]:
        tg[label] = time_round(n, g, agg, enc, legacy=(label == "dense"))
        emit("fed_round_step", f"round_{label}_us_g{g}n{n}",
             round(tg[label], 1))
    emit("fed_round_step", f"round_speedup_g{g}n{n}",
         round(tg["dense"] / tg["fused"], 2))


def cohort_round(fast=False):
    """Streaming massive-cohort round (``cohort=stream``): one jitted round
    at n = 1k / 10k clients on the width-1024 MLP (~1.3M coords), client
    shards scanned through the fused encode with only the reduced wire
    accumulator carried across shards. Emits wall-clock plus XLA peak-temp
    estimates next to the analytic working sets — the O(n*d) f32 stack the
    one-shot vmap path would materialize vs the O(shard*d/8) wire slab
    streaming actually touches. n = 100k compiles (and reports the memory
    estimate) without executing. When more than one device is visible
    (``--devices D``), also times the shard_map-partitioned round
    (``stream(devices=D)``, one O(d) psum) at the smaller size and emits
    scaling-efficiency rows — on forced host devices (threads on one core)
    these measure partition OVERHEAD, efficiency ~ 1/D by construction;
    wall-clock scaling needs real chips."""
    from repro.fed import sampling
    dim, classes, width = 256, 10, (64 if fast else 1024)
    shard = 32 if fast else 64
    micro = 2
    init, loss_fn, _ = mlp_loss_builder(dim, classes, width=width)
    params = init(jax.random.PRNGKey(0))
    d = sum(p.size for p in jax.tree_util.tree_leaves(params))
    nb = -(-d // 8)
    emit("cohort_round", "cohort_model_coords", d)
    emit("cohort_round", "cohort_shard_auto_clients", fedavg.auto_shard_size(d))
    comp = compression.Pipeline("zsign(z=1,sigma=0.05)")

    def build(n, cohort):
        cfg = fedavg.FedConfig(n_clients=n, client_groups=1, client_lr=0.05,
                               server_lr=sign_slr(0.01, 1, 0.05, 0.05))
        ctx = fedavg.RoundContext(weights_are_mask=True, cohort=cohort)
        step = jax.jit(fedavg.build_round_step(loss_fn, comp, cfg, ctx),
                       donate_argnums=0)
        kx, ky = jax.random.split(jax.random.PRNGKey(2))
        batch = {"x": jax.random.normal(kx, (1, n, 1, micro, dim)),
                 "y": jax.random.randint(ky, (1, n, 1, micro), 0, classes)}
        sampler = sampling.CohortSampler(total_clients=n,
                                         per_round=max(1, n // 10), seed=3)
        mask = jnp.asarray(sampler.dense(*sampler.sample(), (1, n)))
        state = fedavg.init_server_state(
            jax.tree.map(jnp.array, params), cfg, comp, jax.random.PRNGKey(1))
        return step.lower(state, batch, mask).compile(), state, batch, mask

    def temp_mb(compiled):
        try:
            t = compiled.memory_analysis().temp_size_in_bytes
        except Exception:
            return None
        return round(t / 1e6, 1)

    sizes = [256, 1024] if fast else [1024, 10_000]
    t_stream = {}
    for n in sizes:
        compiled, state, batch, mask = build(n, f"stream(shard={shard})")
        emit("cohort_round", f"cohort_temp_stream_MB_n{n}", temp_mb(compiled))
        iters = 1 if n > 2048 else 2
        state, m = compiled(state, batch, mask)  # warmup; rebind donated state
        jax.block_until_ready((state, m))
        if n == sizes[0]:
            # recorded by the round itself (RoundMetrics), not hardcoded
            emit("cohort_round", "cohort_shard_clients", int(m.shard_clients))
        t0 = time.perf_counter()
        for _ in range(iters):
            state, m = compiled(state, batch, mask)
        jax.block_until_ready((state, m))
        t_stream[n] = (time.perf_counter() - t0) / iters * 1e6
        emit("cohort_round", f"cohort_round_stream_us_n{n}",
             round(t_stream[n], 1))
        emit("cohort_round", f"cohort_wire_shard_bytes_n{n}", shard * nb)
        emit("cohort_round", f"cohort_wire_full_stack_bytes_n{n}", n * nb)
        emit("cohort_round", f"cohort_dense_f32_bytes_n{n}", n * d * 4)

    # vmap contrast at the smallest size, compile-only: the full-cohort
    # (n, d) f32 working set is exactly what streaming deletes — executing
    # it at width 1024 would allocate ~n*d*4 bytes of temp.
    compiled_v, *_ = build(sizes[0], "vmap")
    emit("cohort_round", f"cohort_temp_vmap_MB_n{sizes[0]}",
         temp_mb(compiled_v))

    # multi-device partition (--devices D): the shard sequence split over a
    # 1-D `clients` mesh, one O(d) fp32 psum before decode. On forced host
    # devices D "devices" are threads on the SAME core, so the ideal-speedup
    # denominator D in the efficiency row makes efficiency ~ 1/D — the row
    # tracks partition overhead honestly rather than simulating hardware.
    dc = jax.device_count()
    if dc >= 2:
        nd = sizes[0]
        td = {}
        for dev in [1] + [dd for dd in (2, 4, 8) if dd <= dc]:
            compiled, state, batch, mask = build(
                nd, f"stream(shard={shard},devices={dev})")
            state, m = compiled(state, batch, mask)  # warmup
            jax.block_until_ready((state, m))
            t0 = time.perf_counter()
            state, m = compiled(state, batch, mask)
            jax.block_until_ready((state, m))
            td[dev] = (time.perf_counter() - t0) * 1e6
            emit("cohort_round", f"cohort_round_stream_us_n{nd}_d{dev}",
                 round(td[dev], 1))
        for dev in sorted(td)[1:]:
            emit("cohort_round", f"cohort_stream_scaling_eff_n{nd}_d{dev}",
                 round(td[1] / (dev * td[dev]), 3))

        # the acceptance-bar pair at the large size: D=1 is the main loop's
        # stream row (identical plan — devices defaults to 1), D=4 measured
        # here with one timed round.
        nbig = sizes[-1]
        if not fast and dc >= 4 and nbig in t_stream:
            emit("cohort_round", f"cohort_round_stream_us_n{nbig}_d1",
                 round(t_stream[nbig], 1))
            compiled, state, batch, mask = build(
                nbig, f"stream(shard={shard},devices=4)")
            state, m = compiled(state, batch, mask)  # warmup
            jax.block_until_ready((state, m))
            t0 = time.perf_counter()
            state, m = compiled(state, batch, mask)
            jax.block_until_ready((state, m))
            t4 = (time.perf_counter() - t0) * 1e6
            emit("cohort_round", f"cohort_round_stream_us_n{nbig}_d4",
                 round(t4, 1))
            emit("cohort_round", f"cohort_stream_scaling_eff_n{nbig}_d4",
                 round(t_stream[nbig] / (4 * t4), 3))

    if not fast:
        t0 = time.perf_counter()
        compiled_big, *_ = build(100_000, f"stream(shard={shard})")
        emit("cohort_round", "cohort_compile_s_n100000",
             round(time.perf_counter() - t0, 1))
        emit("cohort_round", "cohort_temp_stream_MB_n100000",
             temp_mb(compiled_big))


def robust_agg(fast=False):
    """Byzantine-robust compressed-domain aggregation overhead: one jitted
    round on the width-1024 MLP (~1.3M coords, n=32 clients) per ``agg=``
    mode. vote/trimmed/median replace the popcount mean-reduce with the
    carried int32 (signed_count, n_live) vote pair + a closed-form decode —
    same payload bytes, same single reduce shape — so the robust round must
    land within 1.3x of the mean round (the acceptance floor this bench
    records). Also times one round under the sign-flip adversary to show
    fault injection is wire-local (XOR on the uint8 stack, no extra
    reduce)."""
    dim, classes, width = 256, 10, (128 if fast else 1024)
    micro = 8
    n = 32
    iters, warmup = (3, 1) if fast else (5, 2)
    init, loss_fn, _ = mlp_loss_builder(dim, classes, width=width)
    params = init(jax.random.PRNGKey(0))
    d = sum(p.size for p in jax.tree_util.tree_leaves(params))
    emit("robust_agg", "robust_agg_model_coords", d)

    def time_round(spec, adversary="none"):
        cfg = fedavg.FedConfig(n_clients=n, client_lr=0.05,
                               server_lr=sign_slr(0.01, 1, 0.05, 0.05))
        kx, ky = jax.random.split(jax.random.PRNGKey(2))
        batch = {"x": jax.random.normal(kx, (1, n, 1, micro, dim)),
                 "y": jax.random.randint(ky, (1, n, 1, micro), 0, classes)}
        mask = jnp.ones((1, n))
        comp = compression.Pipeline(spec)
        ctx = fedavg.RoundContext(weights_are_mask=True, adversary=adversary)
        step = jax.jit(fedavg.build_round_step(loss_fn, comp, cfg, ctx),
                       donate_argnums=0)
        state = fedavg.init_server_state(
            jax.tree.map(jnp.array, params), cfg, comp, jax.random.PRNGKey(1))
        return _time_donated_rounds(step, state, batch, mask, iters, warmup)

    times = {}
    for mode, spec in [("mean", "zsign(z=1,sigma=0.05)"),
                       ("vote", "zsign(z=1,sigma=0.05,agg=vote)"),
                       ("trimmed", "zsign(z=1,sigma=0.05,agg=trimmed,"
                                   "trim_f=2)"),
                       ("median", "zsign(z=1,sigma=0.05,agg=median)")]:
        times[mode] = time_round(spec)
        emit("robust_agg", f"robust_agg_round_us_{mode}_n{n}",
             round(times[mode], 1))
    for mode in ("vote", "trimmed", "median"):
        emit("robust_agg", f"robust_agg_overhead_x_{mode}_n{n}",
             round(times[mode] / times["mean"], 3))
    t_adv = time_round("zsign(z=1,sigma=0.05,agg=vote)",
                       adversary="sign_flip(f=8)")
    emit("robust_agg", f"robust_agg_round_us_vote_signflip_n{n}",
         round(t_adv, 1))
    emit("robust_agg", f"robust_agg_adversary_overhead_x_n{n}",
         round(t_adv / times["vote"], 3))


def cv_round(fast=False):
    """Compressed-SCAFFOLD control-variate overhead: one jitted round on
    the width-1024 MLP (~1.3M coords, n=32 clients) with and without the
    ``cv`` stage. The correction q = p - eta*(c_i - c) and both variate
    updates are O(d) elementwise on buffers the round already touches, and
    the wire is unchanged (1 bit/coord), so the cv round must land within
    1.3x of plain ``zsign_packed`` — the acceptance floor this bench
    records."""
    dim, classes, width = 256, 10, (128 if fast else 1024)
    micro = 8
    n = 32
    iters, warmup = (3, 1) if fast else (5, 2)
    init, loss_fn, _ = mlp_loss_builder(dim, classes, width=width)
    params = init(jax.random.PRNGKey(0))
    d = sum(p.size for p in jax.tree_util.tree_leaves(params))
    emit("cv_round", "round_cv_model_coords", d)

    def time_round(spec):
        cfg = fedavg.FedConfig(n_clients=n, client_lr=0.05,
                               server_lr=sign_slr(0.01, 1, 0.05, 0.05))
        kx, ky = jax.random.split(jax.random.PRNGKey(2))
        batch = {"x": jax.random.normal(kx, (1, n, 1, micro, dim)),
                 "y": jax.random.randint(ky, (1, n, 1, micro), 0, classes)}
        mask = jnp.ones((1, n))
        comp = compression.Pipeline(spec)
        ctx = fedavg.RoundContext(weights_are_mask=True)
        step = jax.jit(fedavg.build_round_step(loss_fn, comp, cfg, ctx),
                       donate_argnums=0)
        state = fedavg.init_server_state(
            jax.tree.map(jnp.array, params), cfg, comp, jax.random.PRNGKey(1))
        return _time_donated_rounds(step, state, batch, mask, iters, warmup)

    t_base = time_round("zsign_packed(z=1,sigma=0.05)")
    t_cv = time_round("cv(eta=0.5,beta=0.5)|zsign_packed(z=1,sigma=0.05)")
    emit("cv_round", f"round_cv_baseline_us_n{n}", round(t_base, 1))
    emit("cv_round", f"round_cv_us_n{n}", round(t_cv, 1))
    emit("cv_round", f"round_cv_overhead_x_n{n}", round(t_cv / t_base, 3))


def async_round(fast=False):
    """Async deadline rounds (``round_mode=async``) vs the sync straggler
    barrier. Two row families: (1) simulated round close time under
    heavy-tail latency models — the async round closes at the p90
    deadline while the sync barrier pays the slowest live straggler, so
    the p90 close-time ratio is the wall-clock claim of async mode; (2)
    measured driver overhead — the async host loop at zero latency runs
    the same per-shard computation as the sync ``stream(feed=host)``
    driver (they are pinned bit-identical), so the round-time ratio
    isolates the event-loop bookkeeping cost."""
    from repro.core.context import RoundModePolicy
    from repro.fed.async_server import parse_latency, simulate_close_times
    rounds = 10 if fast else 50
    n_sim = 64 if fast else 256
    for label, spec in [("lognormal",
                         "lognormal(median=1.0,sigma=1.0,seed=7)"),
                        ("pareto", "pareto(xm=1.0,alpha=1.5,seed=7)")]:
        model = parse_latency(spec)
        draws = np.concatenate([model.sample(r, n_sim)
                                for r in range(rounds)])
        deadline = float(np.percentile(draws[np.isfinite(draws)], 90))
        pol = RoundModePolicy.parse(
            f"async(deadline={deadline},staleness=poly(0.5))")
        ct = simulate_close_times(pol, model, rounds, n_sim)
        p50a, p90a = np.percentile(ct[:, 0], [50, 90])
        p50s, p90s = np.percentile(ct[:, 1], [50, 90])
        emit("async_round", f"async_deadline_p90_{label}_n{n_sim}",
             round(deadline, 3))
        emit("async_round", f"async_close_p50_{label}_n{n_sim}",
             round(float(p50a), 3))
        emit("async_round", f"async_close_p90_{label}_n{n_sim}",
             round(float(p90a), 3))
        emit("async_round", f"async_sync_barrier_p50_{label}_n{n_sim}",
             round(float(p50s), 3))
        emit("async_round", f"async_sync_barrier_p90_{label}_n{n_sim}",
             round(float(p90s), 3))
        emit("async_round", f"async_close_speedup_p90_{label}_n{n_sim}",
             round(float(p90s / p90a), 2))

    # measured driver overhead at zero latency (identical computation)
    dim, classes, width = 256, 10, (128 if fast else 512)
    micro, n, shard = 8, 32, 8
    iters, warmup = (2, 1) if fast else (5, 2)
    init, loss_fn, _ = mlp_loss_builder(dim, classes, width=width)
    params = init(jax.random.PRNGKey(0))

    def time_host_round(round_mode):
        cfg = fedavg.FedConfig(n_clients=n, client_lr=0.05,
                               server_lr=sign_slr(0.01, 1, 0.05, 0.05))
        kx, ky = jax.random.split(jax.random.PRNGKey(2))
        batch = {"x": jax.random.normal(kx, (1, n, 1, micro, dim)),
                 "y": jax.random.randint(ky, (1, n, 1, micro), 0, classes)}
        mask = jnp.ones((1, n))
        comp = compression.Pipeline("zsign(z=1,sigma=0.05)")
        ctx = fedavg.RoundContext(weights_are_mask=True,
                                  cohort=f"stream(shard={shard},feed=host)",
                                  round_mode=round_mode)
        # host-loop drivers: not jitted, not donated (the per-shard kernel
        # is jitted and cached inside)
        step = fedavg.build_round_step(loss_fn, comp, cfg, ctx)
        state = fedavg.init_server_state(
            jax.tree.map(jnp.array, params), cfg, comp, jax.random.PRNGKey(1))
        for _ in range(warmup):
            state, m = step(state, batch, mask)
        jax.block_until_ready((state.params, m))
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            for _ in range(iters):
                state, m = step(state, batch, mask)
            jax.block_until_ready((state.params, m))
            best = min(best, (time.perf_counter() - t0) / iters * 1e6)
        return best

    t_sync = time_host_round("sync")
    t_async = time_host_round("async(deadline=1.0)")
    emit("async_round", f"async_round_sync_host_us_n{n}", round(t_sync, 1))
    emit("async_round", f"async_round_async_us_n{n}", round(t_async, 1))
    emit("async_round", f"async_driver_overhead_x_n{n}",
         round(t_async / t_sync, 3))


def kernel_throughput(fast=False):
    """Pallas compression kernel vs pure-jnp reference (interpret mode on CPU
    measures correctness-path overhead; compiled-TPU numbers on hardware)."""
    from repro.kernels.zsign import ops, ref
    size = 2 ** 20 if not fast else 2 ** 16
    x = jax.random.normal(jax.random.PRNGKey(0), (size,))
    nz = jax.random.normal(jax.random.PRNGKey(1), (size,))

    ref_fn = jax.jit(lambda a, b: ref.zsign_compress_ref(a, b, 0.5))
    us_ref = timeit(ref_fn, x, nz, iters=5 if fast else 20)
    emit("kernel_throughput", f"ref_jnp_us_{size}", round(us_ref, 1))
    emit("kernel_throughput", "compression_ratio_wire", 32.0)
    emit("kernel_throughput", f"ref_jnp_GBps_{size}",
         round(size * 4 / (us_ref * 1e-6) / 1e9, 2))

    # flat-codec encode path (wire.pack_flat): what every sign-family
    # compressor runs when the Pallas kernel is not selected.
    from repro.core import wire
    pack_fn = jax.jit(lambda a, b: wire.pack_flat(a + 0.5 * b))
    us_pack = timeit(pack_fn, x, nz, iters=5 if fast else 20)
    emit("kernel_throughput", f"codec_pack_flat_us_{size}", round(us_pack, 1))
    emit("kernel_throughput", f"codec_pack_flat_GBps_{size}",
         round(size * 4 / (us_pack * 1e-6) / 1e9, 2))

    # server-side weighted sign-reduce: legacy dense-matrix decode vs the
    # fused paths (mask popcount + general bit-sliced) on a 32-client stack.
    n, nb = 32, size // 8
    payload = jax.random.randint(jax.random.PRNGKey(2), (n, nb), 0, 256,
                                 jnp.int32).astype(jnp.uint8)
    live = jnp.ones((n,), jnp.float32)
    for label, fn in [("dense", wire.unpack_sum_dense),
                      ("mask", wire.unpack_sum_mask),
                      ("weighted", wire.unpack_sum)]:
        us = timeit(jax.jit(fn), payload, live, iters=5 if fast else 20)
        emit("kernel_throughput", f"sign_reduce_{label}_us_n{n}_{size}",
             round(us, 1))
    emit("kernel_throughput", f"sign_reduce_wire_bytes_n{n}_{size}", n * nb)


def client_encode(fast=False):
    """Client-side encode: dense jax.random draw + pack ("reference") vs the
    fused counter-based paths, per backend and per z, on a realistic flat
    buffer. The fused rows are what zsign/stosign/zsign_packed now run by
    default; "jnp_chunked" is the bounded-memory scan variant; "pallas" runs
    in interpret mode on CPU (correctness-path cost only — compiled numbers
    need hardware)."""
    size = 2 ** 16 if fast else 2 ** 20
    iters, warmup = (3, 1) if fast else (20, 5)
    x = jax.random.normal(jax.random.PRNGKey(0), (size,))
    key = jax.random.PRNGKey(1)
    emit("client_encode", "encode_coords", size)
    for z, zname in [(1, "z1"), (0, "zinf")]:
        times = {}
        cases = [("reference", "encode_backend=reference"),
                 ("fused_jnp", "encode_backend=jnp"),
                 ("fused_jnp_chunked", "encode_backend=jnp,"
                                       "encode_chunk_tiles=4")]
        if not fast:
            cases.append(("fused_pallas", "encode_backend=pallas"))
        for label, opts in cases:
            comp = compression.Pipeline(f"zsign(z={z},sigma=0.05,{opts})")
            fn = jax.jit(lambda k, f: comp.encode(k, f, None)[0])
            us = timeit(fn, key, x, iters=(1 if label == "fused_pallas"
                                           else iters), warmup=warmup)
            times[label] = us
            emit("client_encode", f"encode_{label}_us_{zname}_{size}",
                 round(us, 1))
            emit("client_encode", f"encode_{label}_GBps_{zname}_{size}",
                 round(size * 4 / (us * 1e-6) / 1e9, 2))
        emit("client_encode", f"encode_fused_speedup_{zname}_{size}",
             round(times["reference"] / times["fused_jnp"], 2))
    # stosign rides the z=inf fused path with sigma = ||flat||
    for label, be in [("reference", "reference"), ("fused_jnp", "jnp")]:
        comp = compression.Pipeline(f"stosign(encode_backend={be})")
        fn = jax.jit(lambda k, f: comp.encode(k, f, None)[0])
        us = timeit(fn, key, x, iters=iters, warmup=warmup)
        emit("client_encode", f"encode_stosign_{label}_us_{size}",
             round(us, 1))


BENCHES = [fig1_consensus_dims, fig2_noise_scales, fig3_noniid,
           fig5_local_steps, fig6_plateau, fig16_qsgd, fig17_dp, table2_bits,
           kernel_throughput, client_encode, fed_round_step, cohort_round,
           robust_agg, cv_round, async_round]

# several benches may merge into one JSON file (kernel + encode rows).
# The key prefix ATTRIBUTES existing rows to their bench so a re-run bench
# replaces ALL of its old rows (renamed/removed metrics included) while
# other benches' rows survive a --only run; every metric a bench emits must
# carry its prefix ("" = the file's default owner).
_JSON_FILES = {"fed_round_step": ("BENCH_round.json", ""),
               "cohort_round": ("BENCH_round.json", "cohort_"),
               "robust_agg": ("BENCH_round.json", "robust_agg_"),
               "cv_round": ("BENCH_round.json", "round_cv_"),
               "async_round": ("BENCH_round.json", "async_"),
               "kernel_throughput": ("BENCH_kernels.json", ""),
               "client_encode": ("BENCH_kernels.json", "encode_")}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_round.json / BENCH_kernels.json")
    ap.add_argument("--devices", type=int, default=0,
                    help="force D host devices (consumed before jax import) "
                         "so cohort_round emits stream(devices=D) rows")
    ap.add_argument("--robust-agg", action="store_true",
                    help="shorthand for --only robust_agg (robust agg-mode "
                         "round overhead rows in BENCH_round.json)")
    ap.add_argument("--async", action="store_true", dest="async_rows",
                    help="shorthand for --only async_round (async deadline "
                         "vs sync-barrier round-latency rows in "
                         "BENCH_round.json)")
    ap.add_argument("--cv", action="store_true", dest="cv_rows",
                    help="shorthand for --only cv_round (control-variate "
                         "round overhead rows in BENCH_round.json)")
    args = ap.parse_args()
    for opt, flag, bench in [("--robust-agg", "robust_agg", "robust_agg"),
                             ("--async", "async_rows", "async_round"),
                             ("--cv", "cv_rows", "cv_round")]:
        if getattr(args, flag):
            if args.only and args.only != bench:
                raise SystemExit(f"{opt} conflicts with --only {args.only}")
            args.only = bench
    print("name,metric,value")
    for b in BENCHES:
        if args.only and b.__name__ != args.only:
            continue
        b(fast=args.fast)
    if args.json:
        by = {}
        for name, metric, value in ROWS:
            by.setdefault(name, {})[metric] = value
        ran_by_file = {}
        for bench, (path, prefix) in _JSON_FILES.items():
            if bench in by:
                ran_by_file.setdefault(path, []).append((bench, prefix))
        for path, ran in ran_by_file.items():
            prefixes = {pfx for b, (p, pfx) in _JSON_FILES.items()
                        if p == path}

            def owner(key):
                # longest matching prefix wins ("" is the default owner)
                best = ""
                for pfx in prefixes:
                    if pfx and key.startswith(pfx) and len(pfx) > len(best):
                        best = pfx
                return best

            ran_prefixes = {pfx for _, pfx in ran}
            merged = {}
            try:
                with open(path) as f:
                    # keep only rows owned by benches that did NOT run —
                    # a re-run bench replaces all of its rows, including
                    # renamed or removed metrics
                    merged = {k: v for k, v in json.load(f).items()
                              if owner(k) not in ran_prefixes}
            except (OSError, ValueError):
                pass
            for bench, _ in ran:
                merged.update(by[bench])
            with open(path, "w") as f:
                json.dump(merged, f, indent=1, sort_keys=True)
            print(f"# wrote {path}")


if __name__ == "__main__":
    main()
