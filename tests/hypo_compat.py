"""Graceful degradation when ``hypothesis`` is absent.

Tier-1 must run green from a bare checkout (jax + numpy + pytest only), so
property tests import ``given``/``settings``/``st`` from here instead of
hypothesis directly. With hypothesis installed you get the real
shrinking/property engine; without it, ``given`` degrades to a fixed-seed
``pytest.mark.parametrize`` over the strategy bounds plus deterministic
random draws — weaker, but the same assertions still run on every case.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False
    import inspect

    import numpy as np
    import pytest

    _N_RANDOM_EXAMPLES = 8

    class _Strategy:
        def __init__(self, lo, hi, draw):
            self.lo, self.hi = lo, hi
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                min_value, max_value,
                lambda rng: int(rng.randint(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **kw):
            return _Strategy(
                float(min_value), float(max_value),
                lambda rng: float(rng.uniform(min_value, max_value)))

    st = _Strategies()

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    def given(*strats):
        def deco(fn):
            # hypothesis binds positional strategies to the RIGHTMOST test
            # parameters (fixtures come first) — mirror that here
            argnames = list(inspect.signature(fn).parameters)[-len(strats):]
            rng = np.random.RandomState(0)
            cases = [tuple(s.lo for s in strats), tuple(s.hi for s in strats)]
            for _ in range(_N_RANDOM_EXAMPLES):
                cases.append(tuple(s.draw(rng) for s in strats))
            if len(strats) == 1:
                cases = [c[0] for c in cases]
            return pytest.mark.parametrize(",".join(argnames), cases)(fn)
        return deco
