"""Compressor unit + property tests over the flat wire-buffer codec.

Includes the codec equivalence suite: encode -> masked aggregate ->
decode -> unflatten through the flat path must match the seed's per-leaf
reference semantics (per-leaf sign/quantize/mask/mean computed directly on
the pytree) to within fp32 tolerance.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypo_compat import given, settings, st

from repro.core import compression as C
from repro.core import wire


def tree_of(x):
    return {"a": jnp.asarray(x, jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.float32)}}


def roundtrip(comp, g, key=None, mask=None, n_clients=1):
    """Full codec path for one client repeated n_clients times: flatten ->
    encode -> stack -> masked aggregate -> mean -> decode -> unflatten."""
    key = key if key is not None else jax.random.PRNGKey(0)
    spec = wire.tree_spec(g)
    flat = spec.flatten(g)
    state = comp.init_state(spec.n_coords)
    encs, st2 = [], None
    for i in range(n_clients):
        e, st2 = comp.encode(jax.random.fold_in(key, i), flat, state)
        encs.append(e)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *encs)
    mask = jnp.ones((n_clients,)) if mask is None else mask
    agg = comp.aggregate(stacked, mask, spec.n_coords)
    n_live = jnp.maximum(jnp.sum(mask), 1.0)
    dec = comp.decode_mean(agg / n_live)
    return spec.unflatten(dec), st2


@pytest.mark.parametrize("spec", [
    "identity", "zsign(z=1,sigma=0.5)",
    "zsign(z=0,sigma=0.5)", "stosign",
    "ef|zsign", "qsgd(s=2)", "ef|topk(frac=0.5)",
    "dp(noise=0.1)|dense", "zsign_packed(z=1,sigma=0.5)",
])
def test_roundtrip_shapes(spec):
    comp = C.Pipeline(spec)
    g = tree_of(np.random.randn(17))
    dec, _ = roundtrip(comp, g, n_clients=2)
    assert jax.tree_util.tree_structure(dec) == jax.tree_util.tree_structure(g)
    for a, b in zip(jax.tree_util.tree_leaves(dec), jax.tree_util.tree_leaves(g)):
        assert a.shape == b.shape


@pytest.mark.parametrize("spec", [
    "zsign(z=1,sigma=0.5)", "stosign", "ef|zsign",
    "zsign_packed(z=1,sigma=0.5)",
])
def test_sign_family_transmits_bitpacked_uint8(spec):
    """Every sign-family compressor ships uint8 at <= 1 bit per coordinate."""
    comp = C.Pipeline(spec)
    assert comp.wire_bits_per_coord <= 1.0
    wf = comp.wire_format()
    assert wf.dtype == "uint8" and wf.bits_per_coord <= 1.0
    d = 10_000
    flat = jnp.asarray(np.random.RandomState(0).randn(d), jnp.float32)
    enc, _ = comp.encode(jax.random.PRNGKey(0), flat,
                         comp.init_state(d))
    packed = enc["packed"] if isinstance(enc, dict) else enc
    assert packed.dtype == jnp.uint8
    # bitpacked: at most ceil over the pack/tile boundary, never d bytes
    assert packed.size < d


def test_zsign_is_sign_when_sigma_zero():
    comp = C.Pipeline("zsign(z=1,sigma=0.0)")
    flat = jnp.asarray([-2.0, -0.1, 0.0, 0.1, 3.0], jnp.float32)
    enc, _ = comp.encode(jax.random.PRNGKey(0), flat, None)
    signs = wire.unpack_signs(enc)[:5]
    np.testing.assert_array_equal(np.asarray(signs),
                                  np.array([-1, -1, 1, 1, 1], np.int8))


def test_zsign_unbiased_estimator_statistically():
    """decode(mean over many independent encodings) ~ g for large sigma."""
    comp = C.Pipeline("zsign(z=0,sigma=5.0)")  # uniform, sigma>|x|
    g = {"w": jnp.asarray(np.linspace(-2, 2, 16), jnp.float32)}
    dec, _ = roundtrip(comp, g, n_clients=4000)
    # uniform noise with sigma > |x|: exactly unbiased (Remark 1)
    np.testing.assert_allclose(np.asarray(dec["w"]), np.asarray(g["w"]),
                               atol=0.4)


def test_qsgd_unbiased():
    comp = C.Pipeline("qsgd(s=1)")
    flat = jnp.asarray(np.random.RandomState(0).randn(32), jnp.float32)
    encs = [comp.encode(jax.random.PRNGKey(i), flat, None)[0]
            for i in range(3000)]
    np.testing.assert_allclose(np.mean(encs, axis=0), np.asarray(flat),
                               atol=0.15)


def test_efsign_error_feedback_contracts():
    """EF residual compensates over repeated encoding of a constant gradient:
    the running decoded average converges to g."""
    comp = C.Pipeline("ef|zsign")
    flat = jnp.asarray([1.0, -0.2, 0.05, 3.0])
    state = comp.init_state(4)
    dec_sum = np.zeros(4)
    T = 200
    for i in range(T):
        enc, state = comp.encode(jax.random.PRNGKey(i), flat, state)
        dec_sum += np.asarray(
            comp.aggregate(jax.tree.map(lambda x: x[None], enc),
                           jnp.ones((1,)), 4)[:4])
    np.testing.assert_allclose(dec_sum / T, np.asarray(flat), atol=0.05)


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=1, max_value=4096))
def test_bitpack_roundtrip(n):
    """pack(unpack) identity for any length (property)."""
    rng = np.random.RandomState(n)
    signs = jnp.asarray(rng.choice([-1, 1], size=((n + 7) // 8) * 8), jnp.int8)
    packed = C.pack_signs(signs)
    unpacked = C.unpack_signs(packed)
    np.testing.assert_array_equal(np.asarray(unpacked), np.asarray(signs))


def test_wire_bits_accounting():
    assert C.Pipeline("zsign").wire_bits_per_coord == 1.0
    assert C.Pipeline("identity").wire_bits_per_coord == 32.0
    assert C.Pipeline("ef|zsign").wire_bits_per_coord == 1.0
    # derived from hyper-parameters, not hardcoded:
    assert C.Pipeline("ef|topk(frac=0.1)").wire_bits_per_coord == \
        pytest.approx(6.4)
    assert C.Pipeline("ef|topk(frac=0.5)").wire_bits_per_coord == \
        pytest.approx(32.0)
    assert C.Pipeline("qsgd(s=1)").wire_bits_per_coord == 2.0
    assert C.Pipeline("qsgd(s=4)").wire_bits_per_coord == 4.0


def test_treespec_flatten_unflatten_roundtrip():
    g = {"a": jnp.arange(5.0), "b": {"c": jnp.ones((3, 4))},
         "d": jnp.zeros((2, 2, 2))}
    spec = wire.tree_spec(g)
    assert spec.n_coords == 5 + 12 + 8
    flat = spec.flatten(g)
    assert flat.shape == (25,) and flat.dtype == jnp.float32
    back = spec.unflatten(flat)
    for a, b in zip(jax.tree_util.tree_leaves(back),
                    jax.tree_util.tree_leaves(g)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # padded buffers: only the leading n_coords entries are read
    back2 = spec.unflatten(jnp.concatenate([flat, jnp.full((7,), 99.0)]))
    for a, b in zip(jax.tree_util.tree_leaves(back2),
                    jax.tree_util.tree_leaves(g)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# codec equivalence vs the seed per-leaf reference path
# ---------------------------------------------------------------------------

def _per_leaf_reference(comp_name, g, noisy_flats, mask, **kw):
    """Seed semantics: per-leaf sign -> masked per-leaf mean -> per-leaf
    decode scale. ``noisy_flats`` are the post-noise flat buffers (one per
    client) so randomized compressors compare exactly."""
    spec = wire.tree_spec(g)
    trees = [spec.unflatten(f) for f in noisy_flats]
    signs = [jax.tree.map(lambda x: jnp.where(x >= 0, 1.0, -1.0), t)
             for t in trees]
    n_live = float(np.maximum(np.sum(np.asarray(mask)), 1.0))
    mean = jax.tree.map(
        lambda *xs: sum(m * x for m, x in zip(np.asarray(mask), xs)) / n_live,
        *signs)
    if comp_name == "zsign":
        from repro.core.noise import eta_z
        scale = eta_z(kw["z"]) * kw["sigma"] if kw["sigma"] > 0 else 1.0
        return jax.tree.map(lambda s: s * scale, mean)
    return mean


@pytest.mark.parametrize("name", ["zsign", "zsign_packed"])
def test_codec_matches_per_leaf_reference_zsign(name):
    """encode -> masked aggregate -> decode through the flat codec ==
    the per-leaf reference, given the same noisy values. Pinned to the
    "reference" encode backend: only the dense jax.random draw can share
    noise values with the external reference draw below (the fused counter
    backends have their own stream — their statistics are covered in
    tests/test_encode_fused.py)."""
    z, sigma, n = 1, 0.7, 5
    comp = C.Pipeline(f"{name}(z={z},sigma={sigma},"
                      f"encode_backend=reference)")
    g = {"a": jnp.asarray(np.random.RandomState(0).randn(37), jnp.float32),
         "b": {"c": jnp.asarray(np.random.RandomState(1).randn(4, 9),
                                jnp.float32)}}
    spec = wire.tree_spec(g)
    flat = spec.flatten(g)
    mask = jnp.asarray([1.0, 0.0, 1.0, 1.0, 0.0])

    from repro.core.noise import sample_z_noise
    keys = [jax.random.fold_in(jax.random.PRNGKey(7), i) for i in range(n)]
    noisy = [flat + sigma * sample_z_noise(k, flat.shape, z) for k in keys]

    encs = [comp.encode(k, flat, None)[0] for k in keys]
    agg = comp.aggregate(jnp.stack(encs), mask, spec.n_coords)
    dec = comp.decode_mean(agg / jnp.sum(mask))
    got = spec.unflatten(dec)

    want = _per_leaf_reference("zsign", g, noisy, mask, z=z, sigma=sigma)
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_codec_matches_per_leaf_reference_identity():
    comp = C.Pipeline("identity")
    g = tree_of(np.random.RandomState(3).randn(23))
    spec = wire.tree_spec(g)
    flat = spec.flatten(g)
    mask = jnp.asarray([1.0, 0.0, 1.0])
    encs = jnp.stack([flat * (i + 1) for i in range(3)])
    agg = comp.aggregate(encs, mask, spec.n_coords)
    got = spec.unflatten(comp.decode_mean(agg / 2.0))
    want = jax.tree.map(lambda x: (1 * x + 3 * x) / 2.0, g)
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_topk_masked_aggregate_scatter():
    comp = C.Pipeline("ef|topk(frac=0.25)")
    d = 16
    flats = [jnp.zeros(d).at[i].set(10.0 + i) for i in range(3)]
    encs, states = [], []
    for f in flats:
        e, s = comp.encode(None, f, comp.init_state(d))
        encs.append(e)
        states.append(s)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *encs)
    mask = jnp.asarray([1.0, 0.0, 1.0])
    agg = comp.aggregate(stacked, mask, d)
    want = np.zeros(d)
    want[0], want[2] = 10.0, 12.0   # client 1 masked out
    np.testing.assert_allclose(np.asarray(agg), want)
    # EF residual conserves p - q
    for f, e, s in zip(flats, encs, states):
        dense = np.zeros(d)
        dense[np.asarray(e["indices"])] = np.asarray(e["values"])
        np.testing.assert_allclose(np.asarray(f), dense + np.asarray(s["ef"]),
                                   atol=1e-6)


def test_efsign_zero_coord_residual_matches_wire():
    """Regression: at p == 0 the wire transmits a +1 bit, so the residual
    must record -scale there (jnp.sign's 0-at-0 would leak +scale/round)."""
    comp = C.Pipeline("ef|zsign")
    flat = jnp.asarray([0.0, 1.0, -1.0, 0.0])
    enc, res = comp.encode(None, flat, comp.init_state(4))
    scale = float(enc["scale"])
    decoded = scale * np.asarray(wire.unpack_signs(enc["packed"]))[:4]
    # EF invariant vs what the SERVER decodes: flat == decoded + residual
    np.testing.assert_allclose(np.asarray(flat),
                               decoded + np.asarray(res["ef"]), atol=1e-6)


@pytest.mark.parametrize("d,frac,chunk", [
    (100, 0.1, 16), (100, 0.25, 32), (257, 0.05, 64), (1000, 0.013, 128),
    (64, 0.5, 16),
])
def test_topk_chunked_exact_equivalence_small_d(d, frac, chunk):
    """Two-stage chunked selection == single full-buffer lax.top_k exactly,
    including tie-breaking (quantized values force cross-chunk ties)."""
    rng = np.random.RandomState(d + chunk)
    # heavy quantization -> many exact ties across chunks
    p = jnp.asarray(np.round(rng.randn(d) * 2) / 2, jnp.float32)
    comp = C.TopKCompressor(name="topk", frac=frac, chunk=chunk)
    ref = C.TopKCompressor(name="topk", frac=frac, chunk=1 << 62)
    k = max(1, int(d * frac))
    idx = comp._select(jnp.abs(p), k)
    _, idx_ref = jax.lax.top_k(jnp.abs(p), k)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(idx_ref))
    # full encode path (values + EF residual) identical too
    e1, s1 = comp.encode(None, p, comp.init_state(d))
    e2, s2 = ref.encode(None, p, ref.init_state(d))
    np.testing.assert_array_equal(np.asarray(e1["indices"]),
                                  np.asarray(e2["indices"]))
    np.testing.assert_array_equal(np.asarray(e1["values"]),
                                  np.asarray(e2["values"]))
    np.testing.assert_array_equal(np.asarray(s1["ef"]), np.asarray(s2["ef"]))


def test_topk_resolve_chunk_law():
    """chunk=0 auto-tune: ~sqrt(d*k) rounded up to a power of two, clamped
    to [4096, 2^20]; explicit positive chunks are honored, negatives
    refused."""
    resolve = C.TopKCodec._resolve_chunk
    # balance point: sqrt(300_000 * 300) ~ 9487 -> next pow2 16384
    assert resolve(300_000, 300) == 16384
    # small buffers clamp to the floor; huge ones to the ceiling
    assert resolve(1_000, 10) == 4096
    assert resolve(10**9, 10**7) == 1 << 20
    for r in (resolve(d, max(1, d // 100)) for d in
              (10**3, 10**5, 10**7, 10**9)):
        assert 4096 <= r <= 1 << 20 and r & (r - 1) == 0  # pow2 in range
    with pytest.raises(ValueError, match="chunk"):
        C.TopKCodec(frac=0.01, chunk=-1)


@pytest.mark.parametrize("d,frac", [(257, 0.05), (100_000, 0.001),
                                    (70_000, 0.02)])
def test_topk_auto_chunk_exact_equivalence(d, frac):
    """chunk=0 (auto) selects the IDENTICAL index set as the single-stage
    reference and as any explicit chunk — the auto-tune is a pure perf
    knob."""
    rng = np.random.RandomState(d)
    p = jnp.asarray(np.round(rng.randn(d) * 2) / 2, jnp.float32)  # ties
    k = max(1, int(d * frac))
    auto = C.TopKCodec(frac=frac, chunk=0)._select(jnp.abs(p), k)
    _, ref = jax.lax.top_k(jnp.abs(p), k)
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(ref))
    explicit = C.TopKCodec(frac=frac, chunk=8192)._select(jnp.abs(p), k)
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(explicit))


def test_topk_chunked_distribution_large_d():
    """Large d (two-stage path active at the auto-resolved chunk): the
    selected set is exactly the true top-k value multiset."""
    d = 300_000
    comp = C.TopKCompressor(name="topk", frac=0.001)
    k_ = max(1, int(d * comp.frac))
    assert d > C.TopKCodec._resolve_chunk(d, k_)  # chunked path runs
    p = jnp.asarray(np.random.RandomState(0).randn(d), jnp.float32)
    e, _ = comp.encode(None, p, comp.init_state(d))
    k = max(1, int(d * comp.frac))
    want = np.sort(np.partition(np.abs(np.asarray(p)), -k)[-k:])[::-1]
    np.testing.assert_allclose(np.sort(np.abs(np.asarray(e["values"])))[::-1],
                               want)
    # indices consistent with values
    np.testing.assert_allclose(np.asarray(p)[np.asarray(e["indices"])],
                               np.asarray(e["values"]))


def test_efsign_scale_weighted_aggregate():
    """EF aggregation weights each client's signs by its own fp32 scale."""
    comp = C.Pipeline("ef|zsign")
    d = 8
    f1 = jnp.asarray([1.0, -1.0, 2.0, -2.0, 1.0, -1.0, 2.0, -2.0])
    f2 = 4.0 * f1
    e1, _ = comp.encode(None, f1, comp.init_state(d))
    e2, _ = comp.encode(None, f2, comp.init_state(d))
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), e1, e2)
    agg = comp.aggregate(stacked, jnp.ones((2,)), d)[:d]
    want = (np.asarray(e1["scale"]) + np.asarray(e2["scale"])) * \
        np.sign(np.asarray(f1))
    np.testing.assert_allclose(np.asarray(agg), want, rtol=1e-6)
