"""Compressor unit + property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import compression as C


def tree_of(x):
    return {"a": jnp.asarray(x, jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.float32)}}


@pytest.mark.parametrize("name,kw", [
    ("identity", {}), ("zsign", {"z": 1, "sigma": 0.5}),
    ("zsign", {"z": 0, "sigma": 0.5}), ("stosign", {}),
    ("efsign", {}), ("qsgd", {"s": 2}), ("topk", {"frac": 0.5}),
])
def test_roundtrip_shapes(name, kw):
    comp = C.make_compressor(name, **kw)
    g = tree_of(np.random.randn(17))
    st_ = comp.init_state(g)
    enc, st2 = comp.encode(jax.random.PRNGKey(0), g, st_)
    dec = comp.decode_mean(enc)
    assert jax.tree_util.tree_structure(dec) == jax.tree_util.tree_structure(g)
    for a, b in zip(jax.tree_util.tree_leaves(dec), jax.tree_util.tree_leaves(g)):
        assert a.shape == b.shape


def test_zsign_is_sign_when_sigma_zero():
    comp = C.make_compressor("zsign", z=1, sigma=0.0)
    g = tree_of(np.array([-2.0, -0.1, 0.0, 0.1, 3.0]))
    enc, _ = comp.encode(jax.random.PRNGKey(0), g, None)
    np.testing.assert_array_equal(np.asarray(enc["a"]),
                                  np.array([-1, -1, 1, 1, 1], np.int8))


def test_zsign_unbiased_estimator_statistically():
    """decode(mean over many independent encodings) ~ g for large sigma."""
    comp = C.make_compressor("zsign", z=0, sigma=5.0)  # uniform, sigma>|x|
    g = {"w": jnp.asarray(np.linspace(-2, 2, 16), jnp.float32)}
    encs = []
    for i in range(4000):
        e, _ = comp.encode(jax.random.PRNGKey(i), g, None)
        encs.append(e["w"].astype(np.float32))
    mean_enc = {"w": jnp.asarray(np.mean(encs, axis=0))}
    dec = comp.decode_mean(mean_enc)
    # uniform noise with sigma > |x|: exactly unbiased (Remark 1)
    np.testing.assert_allclose(np.asarray(dec["w"]), np.asarray(g["w"]),
                               atol=0.4)


def test_qsgd_unbiased():
    comp = C.make_compressor("qsgd", s=1)
    g = {"w": jnp.asarray(np.random.RandomState(0).randn(32), jnp.float32)}
    encs = [comp.encode(jax.random.PRNGKey(i), g, None)[0]["w"]
            for i in range(3000)]
    np.testing.assert_allclose(np.mean(encs, axis=0), np.asarray(g["w"]),
                               atol=0.15)


def test_efsign_error_feedback_contracts():
    """EF residual stays bounded and compensates over repeated encoding of a
    constant gradient: the running decoded average converges to g."""
    comp = C.make_compressor("efsign")
    g = {"w": jnp.asarray([1.0, -0.2, 0.05, 3.0])}
    state = comp.init_state(g)
    dec_sum = np.zeros(4)
    T = 200
    for i in range(T):
        enc, state = comp.encode(jax.random.PRNGKey(i), g, state)
        dec_sum += np.asarray(enc["w"])
    np.testing.assert_allclose(dec_sum / T, np.asarray(g["w"]), atol=0.05)


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=1, max_value=4096))
def test_bitpack_roundtrip(n):
    """pack(unpack) identity for any length (property)."""
    rng = np.random.RandomState(n)
    signs = jnp.asarray(rng.choice([-1, 1], size=((n + 7) // 8) * 8), jnp.int8)
    packed = C.pack_signs(signs)
    unpacked = C.unpack_signs(packed)
    np.testing.assert_array_equal(np.asarray(unpacked), np.asarray(signs))


def test_wire_bits_accounting():
    assert C.make_compressor("zsign").wire_bits_per_coord == 1.0
    assert C.make_compressor("identity").wire_bits_per_coord == 32.0
