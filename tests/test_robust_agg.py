"""Byzantine-robust compressed-domain aggregation + adversary harness suite.

The robust ``agg=`` modes never leave the compressed domain: for ±1 votes
with a 0/1 participation mask, mean, majority vote, coordinate-wise
trimmed(f) mean and coordinate-wise median are all closed-form
post-processings of the carried int32 (signed_count, n_live) vote pair
(wire.vote_accumulator / wire.vote_decode). This suite pins:

  * the vote pair + every decode law bit-identical to a DENSE ±1 oracle
    (numpy sort over the per-coordinate live votes) under arbitrary masks
    and client counts — property-tested;
  * the additive shard fold: folding the pair at shard sizes 1/7/64 equals
    the one-shot pair bit-exactly, so every cohort plan agrees;
  * D in {1, 2, 4, 8} forced host devices: stream(devices=D) rounds are
    bit-identical to the vmap plan for every robust mode and every
    adversary (skip when fewer devices are visible);
  * jaxpr pins: no (n_total, d) f32 buffer on the streaming robust round,
    and the ONLY cross-device collectives are psums — one int32 pair of
    size <= 2 * d_pad plus the scalar f32 loss;
  * fed/adversary.py: deterministic global-index selection (plan- and
    placement-invariant), scheduling, rotation, payload dispatch, and the
    convergence smoke — agg=vote survives f < n/2 sign-flippers that
    demonstrably degrade agg=mean;
  * the debug-wire membership contract: eager raise on fractional masks,
    checkify-functionalized jit raise, REPRO_DEBUG_WIRE env pickup.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypo_compat import given, settings, st

from repro.core import compression as C
from repro.core import fedavg, wire
from repro.core.context import RoundContext
from repro.fed.adversary import Adversary, parse_adversary

_DC = jax.device_count()


def _devices(d):
    return pytest.param(
        d, marks=pytest.mark.skipif(
            _DC < d, reason=f"needs {d} devices, have {_DC} "
            "(XLA_FLAGS=--xla_force_host_platform_device_count=8)"))


# ---------------------------------------------------------------------------
# dense ±1 oracle
# ---------------------------------------------------------------------------

def _dense_signs(packed: np.ndarray) -> np.ndarray:
    """(n, n_bytes) uint8 -> (n, 8*n_bytes) ±1 f64; coordinate b*8+k is
    bit k of byte b (the wire's little-endian bit layout)."""
    bits = np.unpackbits(packed, axis=1, bitorder="little")
    return bits.astype(np.float64) * 2.0 - 1.0


def _oracle_decode(packed, mask, agg, trim_f=0):
    """Sorted-votes reference for every agg law, coordinate-wise over the
    LIVE clients only."""
    signs = _dense_signs(np.asarray(packed))
    live = signs[np.asarray(mask) > 0]
    n = live.shape[0]
    d = signs.shape[1]
    if n == 0:
        return np.zeros(d, np.float32)
    if agg == "mean":
        return (live.sum(0) / n).astype(np.float32)
    if agg == "vote":
        return np.sign(live.sum(0)).astype(np.float32)
    f_max = (n - 1) // 2
    f = f_max if agg == "median" else min(trim_f, f_max)
    srt = np.sort(live, axis=0)
    kept = srt[f:n - f] if n - 2 * f > 0 else srt[f_max:f_max + 1]
    return kept.mean(0).astype(np.float32)


def _pair(packed, mask, shard=None):
    if shard is None:
        return wire.vote_accumulator(packed, mask)
    acc = None
    for lo in range(0, packed.shape[0], shard):
        acc = wire.vote_accumulator(packed[lo:lo + shard],
                                    mask[lo:lo + shard], acc=acc)
    return acc


# ---------------------------------------------------------------------------
# wire layer: vote pair vs oracle, fold, decode laws
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=1, max_value=70),
       st.integers(min_value=1, max_value=600),
       st.integers(min_value=0, max_value=5))
def test_property_vote_pair_matches_dense_oracle(n, n_bytes, trim_f):
    rng = np.random.RandomState(n * 977 + n_bytes * 7 + trim_f)
    packed = jnp.asarray(rng.randint(0, 256, (n, n_bytes)), jnp.uint8)
    mask = jnp.asarray(rng.randint(0, 2, n).astype(np.float32))
    pair = np.asarray(_pair(packed, mask))
    d = 8 * n_bytes
    signs = _dense_signs(np.asarray(packed))
    live = signs[np.asarray(mask) > 0]
    np.testing.assert_array_equal(pair[0][:d], live.sum(0).astype(np.int32))
    assert (pair[1] == int(mask.sum())).all()
    for agg in ("mean", "vote", "trimmed", "median"):
        got = np.asarray(wire.vote_decode(jnp.asarray(pair), agg,
                                          trim_f=max(trim_f, 1)))[:d]
        want = _oracle_decode(packed, mask, agg, trim_f=max(trim_f, 1))
        np.testing.assert_array_equal(got, want, err_msg=agg)


@pytest.mark.parametrize("shard", [1, 7, 64])
def test_vote_pair_shard_fold_bit_exact(shard):
    """Folding the int32 pair at ANY shard size == the one-shot pair."""
    rng = np.random.RandomState(shard)
    packed = jnp.asarray(rng.randint(0, 256, (130, 48)), jnp.uint8)
    mask = jnp.asarray(rng.randint(0, 2, 130).astype(np.float32))
    np.testing.assert_array_equal(np.asarray(_pair(packed, mask, shard)),
                                  np.asarray(_pair(packed, mask)))


def test_vote_decode_edge_cases():
    """All-dead coordinates decode to 0; over-trim (n <= 2f) degrades to the
    median instead of emitting asymmetric junk; trimmed(0) == mean."""
    pair = jnp.asarray([[0, 3, -3, 1], [0, 3, 3, 3]], jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(wire.vote_decode(pair, "vote")), [0.0, 1.0, -1.0, 1.0])
    got = np.asarray(wire.vote_decode(pair, "trimmed", trim_f=50))
    np.testing.assert_array_equal(
        got, np.asarray(wire.vote_decode(pair, "median")))
    assert got[0] == 0.0
    rng = np.random.RandomState(0)
    packed = jnp.asarray(rng.randint(0, 256, (9, 16)), jnp.uint8)
    mask = jnp.ones(9, jnp.float32)
    pr = _pair(packed, mask)
    np.testing.assert_array_equal(
        np.asarray(wire.vote_decode(pr, "trimmed", trim_f=0)),
        np.asarray(wire.vote_decode(pr, "mean")))


# ---------------------------------------------------------------------------
# spec grammar + validation
# ---------------------------------------------------------------------------

def test_agg_spec_grammar_roundtrip():
    for s in ["zsign_packed(agg=vote)", "zsign(agg=trimmed(f=2))",
              "ef|zsign(agg=vote)", "zsign(agg=median)",
              "zsign(agg=trimmed,trim_f=3)"]:
        p = C.Pipeline(s)
        assert C.Pipeline(p.spec).spec == p.spec, s
    assert C.Pipeline("zsign(agg=trimmed(f=2))").codec.trim_f == 2
    # ef's mean_abs convenience default is gated off for robust modes —
    # mean_abs magnitudes are fractional weights, incompatible with the
    # weights-are-mask vote pair
    assert C.Pipeline("ef|zsign(agg=vote)").codec.scale == "none"
    assert C.Pipeline("ef|zsign").codec.scale == "mean_abs"


def test_agg_spec_validation():
    with pytest.raises(ValueError, match="scale"):
        C.Pipeline("zsign(agg=vote,scale=mean_abs)")
    with pytest.raises(ValueError, match="agg"):
        C.Pipeline("zsign(agg=bogus)")
    with pytest.raises(ValueError, match="trim_f"):
        C.Pipeline("zsign(agg=trimmed)")          # needs f >= 1
    with pytest.raises(ValueError, match="trim_f"):
        C.Pipeline("zsign(agg=vote,trim_f=2)")    # f only with trimmed
    with pytest.raises(ValueError):
        parse_adversary("sign_flip(f=0)")
    with pytest.raises(ValueError):
        parse_adversary("warp(f=1)")
    with pytest.raises(ValueError):
        parse_adversary("sign_flip(f=two)")
    with pytest.raises(ValueError, match="f < n/2"):
        parse_adversary("sign_flip(f=9)").bind(8)
    with pytest.raises(ValueError, match="adversary"):
        RoundContext(adversary="warp(f=1)")


def test_robust_agg_refuses_fractional_weights():
    """agg=vote under weights_are_mask=False is a TRACE-TIME error — the
    vote pair counts memberships, fractional data-size weights cannot ride
    it silently."""
    comp = C.Pipeline("zsign(agg=vote)")
    with pytest.raises(ValueError, match="weights_are_mask"):
        comp.aggregate(jnp.zeros((4, 8), jnp.uint8), jnp.ones(4), 64)


# ---------------------------------------------------------------------------
# engine: every plan bit-identical, adversaries plan-invariant
# ---------------------------------------------------------------------------

def _run_rounds(spec, cohort, *, n=16, d=96, rounds=3, adversary="none",
                mask=None, seed=5):
    comp = C.Pipeline(spec)
    cfg = fedavg.FedConfig(n_clients=n, client_lr=0.05, server_lr=0.1)
    ctx = RoundContext(cohort=cohort, weights_are_mask=True,
                       adversary=adversary)
    loss_fn = lambda p, b: 0.5 * jnp.sum((p["x"] - b["y"]) ** 2)
    step = fedavg.build_round_step(loss_fn, comp, cfg, ctx)
    if "feed=host" not in cohort:
        step = jax.jit(step)
    y = jax.random.normal(jax.random.PRNGKey(seed), (1, n, 1, d))
    st = fedavg.init_server_state({"x": jnp.zeros(d)}, cfg, comp,
                                  jax.random.PRNGKey(1))
    mask = jnp.ones((1, n)) if mask is None else mask
    out = []
    for _ in range(rounds):
        st, m = step(st, {"y": y}, mask)
        out.append(np.asarray(st.params["x"]))
    return out


_MASK16 = jnp.ones((1, 16)).at[0, jnp.asarray([1, 4, 9, 13])].set(0.0)

_ROBUST_SPECS = ["zsign_packed(agg=vote)", "zsign_packed(agg=trimmed(f=2))",
                 "zsign_packed(agg=median)", "ef|zsign(agg=vote)"]

_ADVERSARIES = ["sign_flip(f=4)", "byte_corrupt(f=2,p=0.2)",
                "collude(f=4,rotate=true)", "dropout(f=3)",
                "sign_flip(f=4,every=2,start=1)"]


@pytest.mark.parametrize("spec", _ROBUST_SPECS)
@pytest.mark.parametrize("shard", [1, 7, 64])
def test_robust_stream_bit_identical_to_vmap(spec, shard):
    ref = _run_rounds(spec, "vmap", mask=_MASK16)
    got = _run_rounds(spec, f"stream(shard={shard})", mask=_MASK16)
    for wr, wg in zip(ref, got):
        np.testing.assert_array_equal(wr, wg)
        assert np.isfinite(wr).all()


@pytest.mark.parametrize("adv", _ADVERSARIES)
@pytest.mark.parametrize("shard", [7, 64])
def test_adversary_plan_invariant(adv, shard):
    """Attack selection keys on GLOBAL client index + round + seed only, so
    every cohort plan sees the identical attack bit-for-bit."""
    ref = _run_rounds("zsign_packed(agg=vote)", "vmap", adversary=adv)
    got = _run_rounds("zsign_packed(agg=vote)", f"stream(shard={shard})",
                      adversary=adv)
    for wr, wg in zip(ref, got):
        np.testing.assert_array_equal(wr, wg)
    # and the attack genuinely perturbs the trajectory
    clean = _run_rounds("zsign_packed(agg=vote)", "vmap")
    assert any(not np.array_equal(a, b) for a, b in zip(ref, clean)), adv


def test_adversary_host_feed_matches_vmap():
    ref = _run_rounds("zsign_packed(agg=vote)", "vmap",
                      adversary="sign_flip(f=4)")
    got = _run_rounds("zsign_packed(agg=vote)", "stream(shard=4,feed=host)",
                      adversary="sign_flip(f=4)")
    for wr, wg in zip(ref, got):
        np.testing.assert_array_equal(wr, wg)


@pytest.mark.parametrize("devices", [_devices(1), _devices(2), _devices(4),
                                     _devices(8)])
@pytest.mark.parametrize("spec", _ROBUST_SPECS[:1] + _ROBUST_SPECS[2:3])
def test_robust_multi_device_bit_identical(devices, spec):
    ref = _run_rounds(spec, "vmap", mask=_MASK16)
    got = _run_rounds(spec, f"stream(shard=2,devices={devices})",
                      mask=_MASK16)
    for wr, wg in zip(ref, got):
        np.testing.assert_array_equal(wr, wg)


@pytest.mark.parametrize("devices", [_devices(2), _devices(4)])
@pytest.mark.parametrize("adv", _ADVERSARIES)
def test_adversary_multi_device_invariant(devices, adv):
    ref = _run_rounds("zsign_packed(agg=vote)", "vmap", adversary=adv)
    got = _run_rounds("zsign_packed(agg=vote)",
                      f"stream(shard=2,devices={devices})", adversary=adv)
    for wr, wg in zip(ref, got):
        np.testing.assert_array_equal(wr, wg)


def test_adversary_selection_deterministic():
    adv = parse_adversary("collude(f=3,rotate=true,seed=9)").bind(16)
    idx = jnp.arange(16, dtype=jnp.int32)
    a = np.asarray(adv._selected(idx, jnp.int32(4)))
    b = np.asarray(adv._selected(idx, jnp.int32(4)))
    np.testing.assert_array_equal(a, b)
    assert a.sum() == 3
    # rotation slides by f per round (mod total)
    c = np.asarray(adv._selected(idx, jnp.int32(5)))
    np.testing.assert_array_equal(np.roll(a, 3), c)
    # schedule gating
    sched = parse_adversary("sign_flip(f=4,every=3,start=6)").bind(16)
    for r, want in [(0, 0), (5, 0), (6, 4), (7, 0), (9, 4)]:
        assert int(np.asarray(
            sched._selected(idx, jnp.int32(r))).sum()) == want, r


def test_adversary_unbound_refuses():
    adv = parse_adversary("sign_flip(f=2)")
    with pytest.raises(ValueError, match="bind"):
        adv._selected(jnp.arange(4, dtype=jnp.int32), jnp.int32(0))


def test_adversary_payload_dispatch():
    adv = parse_adversary("sign_flip(f=2)").bind(4)
    idx = jnp.arange(4, dtype=jnp.int32)
    r = jnp.int32(0)
    pk = jnp.zeros((4, 8), jnp.uint8)
    out = np.asarray(adv.corrupt(pk, idx, r))
    assert (out[:2] == 0xFF).all() and (out[2:] == 0).all()
    coo = {"values": jnp.ones((4, 3)), "indices": jnp.zeros((4, 3), jnp.int32)}
    out = adv.corrupt(coo, idx, r)
    np.testing.assert_array_equal(np.asarray(out["values"])[:2], -1.0)
    dense = jnp.ones((4, 5))
    np.testing.assert_array_equal(np.asarray(adv.corrupt(dense, idx, r))[:2],
                                  -1.0)
    bc = parse_adversary("byte_corrupt(f=2,p=0.5)").bind(4)
    with pytest.raises(ValueError, match="COO"):
        bc.corrupt(coo, idx, r)
    with pytest.raises(ValueError, match="dense"):
        bc.corrupt(dense, idx, r)


# ---------------------------------------------------------------------------
# jaxpr pins: compressed-domain all the way
# ---------------------------------------------------------------------------

def _robust_round_jaxpr(cohort, n_total=32, d=2 * C.ENCODE_TILE):
    comp = C.Pipeline("zsign_packed(agg=vote)")
    cfg = fedavg.FedConfig(n_clients=n_total, client_lr=0.01, server_lr=0.3)
    loss_fn = lambda p, b: 0.5 * jnp.sum((p["x"] - b["y"]) ** 2)
    step = fedavg.build_round_step(
        loss_fn, comp, cfg,
        RoundContext(cohort=cohort, weights_are_mask=True))
    st = fedavg.init_server_state({"x": jnp.zeros(d)}, cfg, comp,
                                  jax.random.PRNGKey(1))
    # scalar per-client targets: any (n_total, d) array in the jaxpr is a
    # genuine full-cohort gradient/payload stack, never input data
    return jax.make_jaxpr(step)(st, {"y": jnp.zeros((1, n_total, 1, 1))},
                                jnp.ones((1, n_total)))


def _walk_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for vv in (v if isinstance(v, (list, tuple)) else (v,)):
                inner = getattr(vv, "jaxpr", vv)
                if hasattr(inner, "eqns"):
                    yield from _walk_eqns(inner)


_COLLECTIVES = frozenset({
    "psum", "all_gather", "all_to_all", "ppermute", "pmin", "pmax",
    "reduce_scatter", "pgather", "pbroadcast", "all_gather_invariant"})


def test_robust_stream_jaxpr_no_full_cohort_buffers():
    n_total, d = 64, 2 * C.ENCODE_TILE
    jaxpr = _robust_round_jaxpr("stream(shard=8)", n_total, d)
    for eqn in _walk_eqns(jaxpr.jaxpr):
        for var in list(eqn.outvars) + list(eqn.invars):
            aval = getattr(var, "aval", None)
            if aval is None or not hasattr(aval, "shape"):
                continue
            shape = tuple(aval.shape)
            if aval.dtype == jnp.float32 and shape[-2:] == (n_total, d):
                raise AssertionError(
                    f"full-cohort (n_total, d) f32 buffer in robust "
                    f"streaming jaxpr: {eqn}")
            if aval.dtype == jnp.uint8 and len(shape) >= 2 and \
                    shape[-2] == n_total and shape[-1] >= d // 8:
                raise AssertionError(
                    f"full-cohort uint8 payload stack in robust "
                    f"streaming jaxpr: {eqn}")


@pytest.mark.parametrize("devices", [_devices(2), _devices(4)])
def test_robust_shard_map_single_int32_pair_psum(devices):
    """Under stream(devices=D) the ONLY cross-device collectives on the
    robust round are psums: the int32 (signed_count, n_live) pair of size
    <= 2 * d_pad and the scalar f32 loss — the vote fold crosses devices in
    the same single reduce as the mean path, never a payload stack."""
    d = 2 * C.ENCODE_TILE
    jaxpr = _robust_round_jaxpr(f"stream(shard=4,devices={devices})",
                                n_total=32, d=d)
    eqns = list(_walk_eqns(jaxpr.jaxpr))
    assert any(e.primitive.name == "shard_map" for e in eqns)
    colls = [e for e in eqns if e.primitive.name in _COLLECTIVES]
    assert colls, "the device fold must end in a psum"
    pair_psums = 0
    for eqn in colls:
        assert eqn.primitive.name == "psum", eqn
        for var in list(eqn.invars) + list(eqn.outvars):
            aval = var.aval
            if aval.ndim == 0:
                assert aval.dtype == jnp.float32, eqn   # the loss scalar
                continue
            assert aval.dtype == jnp.int32, eqn
            assert int(np.prod(aval.shape)) <= 2 * d, eqn
            pair_psums += 1
    assert pair_psums, "the int32 vote pair must cross in a psum"


# ---------------------------------------------------------------------------
# convergence smoke: vote survives what breaks mean
# ---------------------------------------------------------------------------

def test_vote_survives_sign_flippers_mean_degrades():
    """n=16 consensus with f=5 < n/2 sign-flippers. In the ±1 vote domain
    every agg law shares its fixed points (they agree in sign), so the
    attack's damage is to the RATE: the mean estimate's magnitude collapses
    to (h - f)/n = 4/16 of a vote while majority vote still moves a full
    unit step — at a fixed round budget vote has arrived and mean is still
    most of the way out."""
    n, d, rounds, f = 16, 64, 60, 6
    y = 5.0 + jax.random.normal(jax.random.PRNGKey(0), (1, n, 1, d))
    opt = np.asarray(y[0, f:, 0]).mean(0)   # honest clients' consensus
    d0 = float(np.linalg.norm(opt))         # distance from the zero init
    loss_fn = lambda p, b: 0.5 * jnp.sum((p["x"] - b["y"]) ** 2)

    def dist(agg):
        comp = C.Pipeline(f"zsign_packed(agg={agg})")
        # effective sign step = server_lr * client_lr = 0.1 per coordinate
        cfg = fedavg.FedConfig(n_clients=n, client_lr=0.05, server_lr=2.0)
        ctx = RoundContext(cohort="vmap", weights_are_mask=True,
                           adversary=f"sign_flip(f={f})")
        step = jax.jit(fedavg.build_round_step(loss_fn, comp, cfg, ctx))
        st = fedavg.init_server_state({"x": jnp.zeros(d)}, cfg, comp,
                                      jax.random.PRNGKey(1))
        mask = jnp.ones((1, n))
        for _ in range(rounds):
            st, _ = step(st, {"y": y}, mask)
        return float(np.linalg.norm(np.asarray(st.params["x"]) - opt))

    d_vote, d_mean = dist("vote"), dist("mean")
    assert d_vote < 0.2 * d0, (d_vote, d0)          # vote arrived
    assert d_mean > 0.5 * d0, (d_mean, d0)          # mean still far out
    assert d_vote < 0.5 * d_mean, (d_vote, d_mean)


# ---------------------------------------------------------------------------
# debug-wire membership contract
# ---------------------------------------------------------------------------

def test_debug_wire_eager_raise_on_fractional_mask():
    packed = jnp.zeros((4, 8), jnp.uint8)
    ok = jnp.asarray([1.0, 0.0, 1.0, 1.0])
    wire.unpack_sum_mask(packed, ok, debug=True)          # clean: no raise
    wire.vote_accumulator(packed, ok, debug=True)
    bad = jnp.asarray([1.0, 0.5, 1.0, 1.0])
    with pytest.raises(Exception, match="membership"):
        wire.unpack_sum_mask(packed, bad, debug=True)
    with pytest.raises(Exception, match="membership"):
        wire.vote_accumulator(packed, bad, debug=True)


def test_debug_wire_checkified_round():
    """debug_wire under jit: the step must be checkify-functionalized; the
    thrown error carries the membership message. A bare jit refuses to
    trace (the check is not silently dropped)."""
    from jax.experimental import checkify
    comp = C.Pipeline("zsign_packed(agg=vote)")
    cfg = fedavg.FedConfig(n_clients=8, client_lr=0.05, server_lr=0.1)
    ctx = RoundContext(cohort="vmap", weights_are_mask=True, debug_wire=True)
    loss_fn = lambda p, b: 0.5 * jnp.sum((p["x"] - b["y"]) ** 2)
    step = fedavg.build_round_step(loss_fn, comp, cfg, ctx)
    st = fedavg.init_server_state({"x": jnp.zeros(32)}, cfg, comp,
                                  jax.random.PRNGKey(1))
    y = jax.random.normal(jax.random.PRNGKey(3), (1, 8, 1, 32))
    cstep = checkify.checkify(jax.jit(step))
    err, _ = cstep(st, {"y": y}, jnp.ones((1, 8)))
    err.throw()                                           # clean round
    err, _ = cstep(st, {"y": y}, jnp.full((1, 8), 0.5))
    with pytest.raises(Exception, match="membership"):
        err.throw()


def test_debug_wire_env_var_pickup(monkeypatch):
    monkeypatch.setenv("REPRO_DEBUG_WIRE", "1")
    assert RoundContext().debug_wire
    monkeypatch.setenv("REPRO_DEBUG_WIRE", "0")
    assert not RoundContext().debug_wire
    monkeypatch.delenv("REPRO_DEBUG_WIRE")
    assert not RoundContext().debug_wire
    # the context threads the flag onto every sign codec
    ctx = RoundContext(weights_are_mask=True, debug_wire=True)
    assert C.Pipeline("zsign(agg=vote)").with_context(ctx).codec.debug_wire


# ---------------------------------------------------------------------------
# topk coordinate-participation weight
# ---------------------------------------------------------------------------

def test_topk_coord_participation_mean():
    """agg=coord divides each coordinate by ITS OWN reporter count — a
    coordinate reported by 1 of 4 live clients decodes to that client's
    value, not 1/4 of it."""
    comp = C.Pipeline("topk(frac=0.5,agg=coord)")
    codec = comp.codec
    vals = jnp.asarray([[2.0], [4.0], [6.0], [8.0]])
    idx = jnp.asarray([[0], [0], [1], [2]])
    mask = jnp.asarray([1.0, 1.0, 1.0, 0.0])
    enc = {"values": vals, "indices": idx}
    acc = codec.aggregate(enc, mask, 4)
    out = np.asarray(codec.decode_sum(acc, jnp.float32(3.0)))
    np.testing.assert_allclose(out, [3.0, 6.0, 0.0, 0.0])
    # additive fold across shards
    a0 = codec.aggregate({"values": vals[:2], "indices": idx[:2]}, mask[:2], 4)
    a1 = codec.aggregate({"values": vals[2:], "indices": idx[2:]}, mask[2:], 4,
                         acc=a0)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(acc))
