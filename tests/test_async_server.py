"""Async deadline-round driver: exactness + straggler-law suite.

The async driver (fed/async_server.py, ``RoundContext.round_mode =
"async(deadline=T,...)"``) replaces the sync round barrier with a
deadline fold: on-time payloads fold into the current round, late ones
buffer and fold into round r+s at the staleness weight, failures get
dead-client mask semantics. Contract pinned here:

  * ZERO simulated latency + a deadline covering everyone -> the async
    round is BIT-identical (params, residuals, metrics) to the sync
    ``stream(feed=host)`` round — the async shard pass IS the sync host
    driver's computation;
  * a deadline drops EXACTLY the clients the latency model puts past it
    (closed-form with the linear model), and under ``staleness=none``
    the result equals a sync round with those clients masked out —
    residuals frozen, bit-identical;
  * stale folds carry the closed-form law weight ((1+s)^-a poly, 0/1
    cutoff) and show up in the participation metric as fractional
    weight, round by round;
  * ``min_clients=M`` extends the effective deadline to the M-th
    fastest live client;
  * the latency model and the whole driver are deterministic — same
    spec, same bytes — and compose with fed/adversary.py attacks.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compression as C
from repro.core import fedavg
from repro.core.context import RoundContext, RoundModePolicy
from repro.fed.async_server import (LatencyModel, build_async_round_step,
                                    parse_latency, partition_round,
                                    simulate_close_times, staleness_rounds)


# ---------------------------------------------------------------------------
# policy + latency spec parsing
# ---------------------------------------------------------------------------

def test_round_mode_policy_parse():
    assert RoundModePolicy.parse("sync").mode == "sync"
    pol = RoundModePolicy.parse("async(deadline=2.5)")
    assert (pol.mode, pol.deadline, pol.min_clients, pol.staleness) == \
        ("async", 2.5, 0, "none")
    pol = RoundModePolicy.parse(
        "async(deadline=1.0,min_clients=4,staleness=poly(0.5))")
    assert (pol.min_clients, pol.staleness, pol.staleness_arg) == \
        (4, "poly", 0.5)
    pol = RoundModePolicy.parse("async(deadline=1,staleness=cutoff(3))")
    assert (pol.staleness, pol.staleness_arg) == ("cutoff", 3.0)
    # idempotent on an already-parsed policy
    assert RoundModePolicy.parse(pol) is pol
    for bad in ["nope", "async", "async()", "async(deadline=0)",
                "async(deadline=-1)", "sync(deadline=1)",
                "async(deadline=1,staleness=exp(2))",
                "async(deadline=1,frac=2)"]:
        with pytest.raises(ValueError):
            RoundModePolicy.parse(bad)
    with pytest.raises(ValueError):
        RoundContext(round_mode="async(deadline=0)")
    # latency= is an async-only knob
    with pytest.raises(ValueError):
        RoundContext(latency="const(t=1)")
    RoundContext(round_mode="async(deadline=1)", latency="const(t=1)")


def test_stale_weight_closed_form():
    poly = RoundModePolicy.parse("async(deadline=1,staleness=poly(0.7))")
    for s in [1, 2, 5]:
        assert poly.stale_weight(s) == pytest.approx((1.0 + s) ** -0.7)
    assert poly.stale_weight(0) == 1.0
    cut = RoundModePolicy.parse("async(deadline=1,staleness=cutoff(2))")
    assert [cut.stale_weight(s) for s in [0, 1, 2, 3]] == [1.0, 1.0, 1.0, 0.0]
    none = RoundModePolicy.parse("async(deadline=1)")
    assert none.stale_weight(1) == 0.0 and none.stale_weight(0) == 1.0


def test_parse_latency():
    assert parse_latency("zero").kind == "zero"
    m = parse_latency("linear(base=0.5,step=0.25,seed=3)")
    assert (m.kind, m.base, m.step, m.seed) == ("linear", 0.5, 0.25, 3)
    m = parse_latency("lognormal(median=2,sigma=1.5,fail=0.1)")
    assert (m.kind, m.median, m.sigma, m.fail) == ("lognormal", 2.0, 1.5, 0.1)
    assert parse_latency("pareto(xm=1,alpha=2)").alpha == 2.0
    assert parse_latency(m) is m          # idempotent
    for bad in ["warp", "const(q=1)", "const(t=1", "linear(base)",
                "lognormal(fail=1.5)", "pareto(alpha=0)"]:
        with pytest.raises(ValueError):
            parse_latency(bad)


def test_latency_model_deterministic():
    m = parse_latency("lognormal(median=1,sigma=1,fail=0.2,seed=9)")
    a, b = m.sample(3, 64), m.sample(3, 64)
    np.testing.assert_array_equal(a, b)           # same (seed, round)
    assert not np.array_equal(a, m.sample(4, 64))  # new round, new draw
    assert np.any(np.isinf(a))                     # failures draw +inf
    lin = parse_latency("linear(base=1,step=2)")
    np.testing.assert_array_equal(lin.sample(0, 4), [1., 3., 5., 7.])


# ---------------------------------------------------------------------------
# the deadline partition (host-side closed forms)
# ---------------------------------------------------------------------------

def test_staleness_rounds_closed_form():
    # s = ceil(lat / deadline) - 1, clamped to >= 1 for anything late
    np.testing.assert_array_equal(
        staleness_rounds(np.array([1.1, 2.0, 2.1, 5.0, np.inf]), 1.0),
        [1., 1., 2., 4., np.inf])


def test_partition_round_min_clients_extends_deadline():
    pol = RoundModePolicy.parse("async(deadline=0.5,min_clients=4)")
    on_time, s, w, close = partition_round(
        pol, np.arange(8.0), np.ones(8, bool))
    # deadline 0.5 alone admits only client 0; min_clients=4 waits for the
    # 4th fastest live latency (client 3 at t=3)
    np.testing.assert_array_equal(on_time, [1, 1, 1, 1, 0, 0, 0, 0])
    assert close == 3.0
    # dead clients can't satisfy the quorum
    on_time, _, _, _ = partition_round(
        pol, np.arange(8.0), np.arange(8) >= 2)
    np.testing.assert_array_equal(on_time[:2], [0, 0])
    assert int(np.sum(on_time)) == 4


def test_partition_round_drops_failed_clients():
    pol = RoundModePolicy.parse("async(deadline=2,staleness=poly(1))")
    lat = np.array([0.5, np.inf, 3.0, 1.0])
    on_time, s, w, _ = partition_round(pol, lat, np.ones(4, bool))
    np.testing.assert_array_equal(on_time, [1, 0, 0, 1])
    assert w[1] == 0.0 and s[1] == 0        # failure: dead, never folds
    assert s[2] == 1 and w[2] == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# end-to-end: the async driver vs the sync round
# ---------------------------------------------------------------------------

def _run_rounds(spec, ctx_kw, *, n=8, d=64, rounds=3, seed=5, mask=None):
    comp = C.Pipeline(spec)
    cfg = fedavg.FedConfig(n_clients=n, client_lr=0.01, server_lr=0.3)
    ctx = RoundContext(**ctx_kw)
    loss_fn = lambda p, b: 0.5 * jnp.sum((p["x"] - b["y"]) ** 2)
    step = fedavg.build_round_step(loss_fn, comp, cfg, ctx)
    y = jax.random.normal(jax.random.PRNGKey(seed), (1, n, 1, d))
    st = fedavg.init_server_state({"x": jnp.zeros(d)}, cfg, comp,
                                  jax.random.PRNGKey(1))
    mask = jnp.ones((1, n)) if mask is None else mask
    metrics = []
    for _ in range(rounds):
        st, m = step(st, {"y": y}, mask)
        metrics.append(m)
    return st, metrics


def _assert_state_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a.params["x"]),
                                  np.asarray(b.params["x"]))
    if a.comp_state is not None or b.comp_state is not None:
        for la, lb in zip(jax.tree_util.tree_leaves(a.comp_state),
                          jax.tree_util.tree_leaves(b.comp_state)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


_MASK8 = jnp.ones((1, 8)).at[0, jnp.asarray([1, 4, 6])].set(0.0)


@pytest.mark.parametrize("spec", ["zsign_packed(z=1,sigma=0.7)", "ef|zsign"])
@pytest.mark.parametrize("shard", [3, 8])
def test_async_zero_latency_bit_identical_to_sync(spec, shard):
    """THE invariant: zero latency + a deadline covering every client ->
    the async round is bit-identical to the sync stream round — params,
    EF residuals, and every metric — dead clients included."""
    sync_kw = dict(cohort=f"stream(shard={shard},feed=host)")
    ref, mref = _run_rounds(spec, sync_kw, mask=_MASK8)
    got, mgot = _run_rounds(spec, {**sync_kw,
                                   "round_mode": "async(deadline=1.0)"},
                            mask=_MASK8)
    _assert_state_equal(ref, got)
    for a, b in zip(mref, mgot):
        assert float(a.loss) == float(b.loss)
        assert float(a.participation) == float(b.participation)
        assert float(a.uplink_bits) == float(b.uplink_bits)
        assert int(a.shard_clients) == int(b.shard_clients)


def test_async_deadline_drops_exactly_the_late_clients():
    """linear(base=0,step=1) latency + deadline=2.5 + staleness=none:
    clients 0..2 are on time, 3..7 never compute — the async run must be
    bit-identical (params AND frozen residuals) to a sync run that masks
    clients 3..7 out."""
    got, mg = _run_rounds("ef|zsign",
                          dict(cohort="stream(shard=3,feed=host)",
                               round_mode="async(deadline=2.5)",
                               latency="linear(base=0,step=1)"))
    mask = jnp.ones((1, 8)).at[0, jnp.asarray([3, 4, 5, 6, 7])].set(0.0)
    ref, _ = _run_rounds("ef|zsign",
                         dict(cohort="stream(shard=3,feed=host)"), mask=mask)
    _assert_state_equal(ref, got)
    assert [float(m.participation) for m in mg] == [3.0, 3.0, 3.0]


def test_async_staleness_fold_matches_closed_form_law():
    """poly(1.0) staleness under linear latency: clients 3..5 arrive one
    round late at weight 1/2, clients 6..7 two rounds late at weight 1/3.
    The participation metric is the total folded weight, so the law is
    directly observable round by round:
      round 0: 3 on-time                                  -> 3.0
      round 1: 3 + 3*(1/2)                                -> 4.5
      round 2: 3 + 3*(1/2) + 2*(1/3)                      -> 5.1667"""
    pol = RoundModePolicy.parse("async(deadline=2.5,staleness=poly(1.0))")
    for i, s_want in [(3, 1), (4, 1), (5, 1), (6, 2), (7, 2)]:
        assert max(1, math.ceil(i / 2.5) - 1) == s_want
        assert pol.stale_weight(s_want) == pytest.approx(1 / (1 + s_want))
    _, ms = _run_rounds("ef|zsign",
                        dict(cohort="stream(shard=3,feed=host)",
                             round_mode="async(deadline=2.5,"
                                        "staleness=poly(1.0))",
                             latency="linear(base=0,step=1)"))
    want = [3.0, 3.0 + 3 * 0.5, 3.0 + 3 * 0.5 + 2 / 3]
    for m, w in zip(ms, want):
        assert float(m.participation) == pytest.approx(w, rel=1e-6)


def test_async_cutoff_staleness_keeps_late_payloads_whole():
    """cutoff(s) staleness folds late payloads at weight 1 (within the
    window): with every client live and a cutoff admitting them all, the
    steady-state participation recovers the FULL cohort — nothing is
    down-weighted, only delayed."""
    _, ms = _run_rounds("zsign_packed(z=1,sigma=0.7)",
                        dict(cohort="stream(shard=3,feed=host)",
                             round_mode="async(deadline=2.5,"
                                        "staleness=cutoff(2))",
                             latency="linear(base=0,step=1)"), rounds=4)
    # rounds 0..3: 3 on-time; +3 one-late from r>=1; +2 two-late from r>=2
    want = [3.0, 6.0, 8.0, 8.0]
    assert [float(m.participation) for m in ms] == want


def test_async_composes_with_adversary():
    """fed/adversary.py composes: dropout hits the mask BEFORE the latency
    partition (dropped clients free their deadline slot), sign_flip
    corrupts payload bytes identically under sync and async — and the
    whole composition is deterministic (two runs, same bytes)."""
    kw = dict(cohort="stream(shard=3,feed=host)",
              round_mode="async(deadline=2.5,staleness=poly(1.0))",
              latency="linear(base=0,step=1)")
    for adv in ["sign_flip(f=2)", "dropout(f=3)"]:
        a, ma = _run_rounds("ef|zsign", {**kw, "adversary": adv})
        b, mb = _run_rounds("ef|zsign", {**kw, "adversary": adv})
        _assert_state_equal(a, b)
        assert [float(m.participation) for m in ma] == \
            [float(m.participation) for m in mb]
    # zero latency + adversary: async == sync, attack bytes included
    ref, _ = _run_rounds("ef|zsign",
                         dict(cohort="stream(shard=3,feed=host)",
                              adversary="sign_flip(f=2)"))
    got, _ = _run_rounds("ef|zsign",
                         dict(cohort="stream(shard=3,feed=host)",
                              round_mode="async(deadline=1.0)",
                              adversary="sign_flip(f=2)"))
    _assert_state_equal(ref, got)


def test_async_poly_rejects_weights_are_mask_pipelines():
    """Fractional stale weights break the static weights_are_mask 0/1
    contract (vote/popcount laws) — the builder must refuse the combo."""
    comp = C.Pipeline("zsign_packed(z=1,sigma=0.7)")
    cfg = fedavg.FedConfig(n_clients=8, client_lr=0.01, server_lr=0.3)
    ctx = RoundContext(round_mode="async(deadline=1,staleness=poly(0.5))",
                       weights_are_mask=True)
    with pytest.raises(ValueError, match="weights_are_mask"):
        fedavg.build_round_step(lambda p, b: jnp.sum(p["x"]), comp, cfg, ctx)


def test_simulate_close_times_beats_sync_barrier_on_heavy_tail():
    """The benchmark's row source: under a heavy-tail latency model the
    async close (the deadline) sits far below the sync barrier (the
    slowest straggler) at the tail percentiles."""
    pol = RoundModePolicy.parse("async(deadline=2.0,staleness=poly(0.5))")
    ct = simulate_close_times(
        pol, parse_latency("lognormal(median=1.0,sigma=1.0,seed=3)"),
        rounds=50, total=64)
    assert ct.shape == (50, 2)
    assert np.percentile(ct[:, 0], 90) <= pol.deadline + 1e-12
    assert np.percentile(ct[:, 0], 90) < 0.5 * np.percentile(ct[:, 1], 90)
    # zero latency: both close instantly (no idle deadline wait)
    ct0 = simulate_close_times(pol, parse_latency("zero"), 3, 8)
    np.testing.assert_array_equal(ct0, 0.0)
