"""Roofline methodology tests.

1. The load-bearing discovery: XLA cost_analysis counts while-loop bodies
   ONCE (so scanned-layer frameworks under-report by ~L x) — pinned here so
   a jax upgrade that fixes it flips the test and we notice.
2. The loop-aware collective accounting recovers trip counts correctly.
3. The analytic term model agrees with hand-computed numbers.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import roofline as RF
from repro.launch.dryrun import collective_bytes, collective_bytes_naive, \
    _parse_computations, _trip_count
from repro.configs.common import get_arch, SHAPES


def _flops(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    cost = c.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    return float(cost.get("flops", 0.0))


def test_cost_analysis_counts_scan_body_once():
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)

    def f_scan(x, w):
        def body(x, _):
            return jnp.tanh(x @ w), ()
        return jax.lax.scan(body, x, None, length=10)[0]

    def f_unroll(x, w):
        for _ in range(10):
            x = jnp.tanh(x @ w)
        return x

    f1, f2 = _flops(f_scan, x, w), _flops(f_unroll, x, w)
    # scan body counted once: ratio ~ 10 (allow slack for fusion wrappers)
    assert f2 / f1 > 5.0, (
        "cost_analysis now multiplies while trip counts — the analytic "
        "correction in launch/roofline.py can be retired")


def test_trip_count_recovery():
    def f(x):
        def body(x, _):
            return jnp.tanh(x) * 1.5, ()
        return jax.lax.scan(body, x, None, length=7)[0]

    hlo = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64,), jnp.float32)).compile().as_text()
    comps = _parse_computations(hlo)
    trips = [_trip_count(comps.get(c, []))
             for c in comps if "cond" in c.lower() or True]
    assert 7 in trips or any(t == 7 for t in trips)


def test_loop_aware_collectives_ge_naive():
    # any HLO: loop-aware total >= flat total
    hlo = """
ENTRY %main (p: f32[8]) -> f32[8] {
  %p = f32[8] parameter(0)
  ROOT %r = f32[8] add(%p, %p)
}
"""
    assert collective_bytes(hlo)["total"] == 0
    assert collective_bytes_naive(hlo)["total"] == 0


def test_param_counts_exact():
    arch = get_arch("qwen2_0_5b")
    pc = RF.param_counts(arch)
    # qwen2-0.5b is ~0.49B params (public number 494M)
    assert 0.4e9 < pc["total"] < 0.6e9
    assert pc["active"] == pc["total"]  # dense: no inactive experts


def test_moe_active_counts():
    arch = get_arch("granite_moe_1b_a400m")
    pc = RF.param_counts(arch)
    assert pc["expert"] > 0
    assert pc["active"] < pc["total"]
    # top-8 of 32 experts: ~25% of expert params active
    frac = (pc["active"] - (pc["total"] - pc["expert"])) / pc["expert"]
    assert abs(frac - 8 / 32) < 1e-6


def test_terms_sane_for_train_cell():
    from repro.launch.sharding import make_plan
    from repro.launch.mesh import make_production_mesh
    # plan shapes only — no devices needed beyond defaults
    arch = get_arch("qwen2_0_5b")
    shape = SHAPES["train_4k"]

    class _M:  # minimal mesh stub for make_plan
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    plan = make_plan(arch, shape, _M())
    terms = RF.train_terms(arch, shape, plan, coll_bytes_per_dev=8e9,
                           multi_pod=False)
    s = terms.seconds()
    assert 0.05 < s["compute"] < 0.5          # ~0.1 s / round / device
    assert terms.model_flops_total > 1e15
    assert 0 < terms.roofline_fraction() <= 1.0
