"""Synthetic federated data: the Dirichlet label-skew partitioner.

``dirichlet_partition`` drives every heterogeneity experiment (paper §4.3,
examples/scaffold_heterogeneous.py), so its statistical law is pinned here:
per-client label proportions follow a symmetric Dirichlet(alpha) per class —
alpha -> 0 concentrates each class on few clients (extreme non-i.i.d.),
alpha -> inf recovers the uniform i.i.d. split. Plus the boring-but-vital
invariants: fixed-seed determinism and exact index-set partitioning, down to
the empty-client edge case when clients outnumber samples.
"""
import numpy as np
import pytest

from repro.data import synthetic


def _labels(n_classes=10, per=400, seed=3):
    rng = np.random.RandomState(seed)
    return rng.permutation(np.repeat(np.arange(n_classes), per))


def test_dirichlet_partition_deterministic():
    y = _labels()
    a = synthetic.dirichlet_partition(y, 8, alpha=0.3, seed=11)
    b = synthetic.dirichlet_partition(y, 8, alpha=0.3, seed=11)
    assert len(a) == len(b) == 8
    for pa, pb in zip(a, b):
        np.testing.assert_array_equal(pa, pb)
    # a different seed reshuffles the allocation
    c = synthetic.dirichlet_partition(y, 8, alpha=0.3, seed=12)
    assert any(pa.shape != pc.shape or (pa != pc).any()
               for pa, pc in zip(a, c))


@pytest.mark.parametrize("alpha", [0.05, 1.0, 100.0])
def test_dirichlet_partition_is_a_partition(alpha):
    """Every sample index lands on exactly one client, for any skew."""
    y = _labels()
    parts = synthetic.dirichlet_partition(y, 7, alpha=alpha, seed=0)
    cat = np.concatenate(parts)
    assert cat.size == y.size
    np.testing.assert_array_equal(np.sort(cat), np.arange(y.size))


def _mean_top_label_share(y, parts):
    """Average over non-empty clients of the share their MOST common label
    holds — 1/n_classes at perfect uniformity, 1.0 at one-label clients."""
    shares = []
    for p in parts:
        if p.size == 0:
            continue
        counts = np.bincount(y[p], minlength=int(y.max()) + 1)
        shares.append(counts.max() / counts.sum())
    return float(np.mean(shares))


def test_dirichlet_skew_increases_as_alpha_drops():
    """The label-skew law: concentration is monotone in 1/alpha. At
    alpha=100 every client sees a near-uniform label mix (top share close
    to the 1/n_classes floor); at alpha=0.05 clients are dominated by a
    couple of classes."""
    y = _labels(n_classes=10, per=500)
    skew = {a: _mean_top_label_share(
                y, synthetic.dirichlet_partition(y, 10, alpha=a, seed=2))
            for a in (0.05, 1.0, 100.0)}
    assert skew[0.05] > skew[1.0] > skew[100.0]
    assert skew[100.0] < 0.2   # near the 0.1 uniform floor
    assert skew[0.05] > 0.5    # dominated by few classes


def test_dirichlet_empty_client_edge_case():
    """More clients than samples: some clients get EMPTY (but valid) index
    arrays, the rest still form an exact partition — and the round-batch
    sampler refuses an empty part loudly rather than silently recycling."""
    y = np.asarray([0, 0, 1, 1], np.int32)
    parts = synthetic.dirichlet_partition(y, 8, alpha=0.1, seed=0)
    assert len(parts) == 8
    assert any(p.size == 0 for p in parts)
    cat = np.concatenate(parts)
    np.testing.assert_array_equal(np.sort(cat), np.arange(y.size))
    for p in parts:  # empty or not, every part indexes into y
        assert p.dtype.kind == "i" or p.size == 0
        assert p.size == 0 or (0 <= p.min() and p.max() < y.size)
    x = np.zeros((y.size, 4), np.float32)
    empty_slot = int(np.argmax([p.size == 0 for p in parts]))
    with pytest.raises(ValueError):
        synthetic.client_batches(x, y, [parts[empty_slot]], (1, 1, 1, 2),
                                 seed=0, round_idx=0)
