"""Per-layer sigma schedule (the ``sigma_sched`` stage).

Contract under test (core/compression.py SigmaSchedule + the engine's
capability-gated TreeSpec threading):

  * the stage is a STATIC geometric per-leaf rescaling m_j = head *
    (tail/head)^(j/(L-1)) of the flat buffer, applied before every other
    stage; the server decode divides the estimate by the same multipliers;
  * bit-exactness: encoding through ``sigma_sched|codec`` equals encoding
    the HAND-SCALED buffer through the plain codec, and decoding equals
    the plain decode divided by m — for sign, qsgd and topk codecs alike;
  * the sign-equivalence identity Sign(m*p + sigma*xi) == Sign(p +
    (sigma/m)*xi): with a uniform multiplier m the whole pipeline is
    bit-identical to the plain codec run at sigma/m;
  * build rules: needs_tree_spec pipelines refuse encode/decode without a
    TreeSpec; at most one sigma_sched; must precede stateful stages;
    refuses cv; multipliers must be positive;
  * engine: the round step threads the TreeSpec automatically (vmap,
    stream, feed=host all bit-identical).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compression as C
from repro.core import fedavg, wire
from repro.core.context import RoundContext


def _tree(seed=0):
    """Three leaves of unequal size — multipliers 2.0, 1.0, 0.5."""
    r = np.random.RandomState(seed)
    return {"a": jnp.asarray(r.randn(3, 4), jnp.float32),
            "b": jnp.asarray(r.randn(7), jnp.float32),
            "c": jnp.asarray(r.randn(5), jnp.float32)}


# ---------------------------------------------------------------------------
# build-time contract
# ---------------------------------------------------------------------------

def test_sigma_sched_build_rules():
    # order/composition refusals, each with its own loud message
    for bad, msg in [("sigma_sched|cv|zsign", "cv"),
                     ("cv|sigma_sched|zsign", "cv"),
                     ("ef|sigma_sched|zsign", "first stage"),
                     ("dp(clip=1.0,noise=0.0)|sigma_sched|zsign",
                      "first stage"),
                     ("sigma_sched|sigma_sched|zsign", "at most one"),
                     ("sigma_sched(head=-1)|zsign", "positive"),
                     ("sigma_sched(head=1,tail=0)|zsign", "positive")]:
        with pytest.raises(ValueError, match=msg):
            C.Pipeline(bad)
    # legal compositions: alone, before ef, before dp, any codec
    for ok in ["sigma_sched|zsign", "sigma_sched(head=2,tail=0.5)|ef|zsign",
               "sigma_sched|dp(clip=1.0,noise=0.0)|zsign_packed",
               "sigma_sched|topk(frac=0.2)", "sigma_sched|qsgd",
               "sigma_sched|dense"]:
        assert C.Pipeline(ok).needs_tree_spec
    assert not C.Pipeline("ef|zsign").needs_tree_spec


def test_sigma_sched_requires_spec_at_both_ends():
    comp = C.Pipeline("sigma_sched(head=2,tail=0.5)|zsign")
    spec = wire.TreeSpec.from_tree(_tree())
    flat = spec.flatten(_tree())
    with pytest.raises(ValueError, match="TreeSpec"):
        comp.encode(jax.random.PRNGKey(0), flat, None)
    enc, _ = comp.encode(jax.random.PRNGKey(0), flat, None, spec=spec)
    agg = comp.aggregate(enc[None], jnp.ones(1), spec.n_coords)
    with pytest.raises(ValueError, match="TreeSpec"):
        comp.decode_sum(agg, jnp.asarray(1.0))
    comp.decode_sum(agg, jnp.asarray(1.0), spec=spec)


def test_multipliers_geometric_law():
    spec = wire.TreeSpec.from_tree(_tree())
    m = np.asarray(C.SigmaSchedule(head=4.0, tail=0.25).multipliers(spec))
    assert m.shape == (spec.n_coords,)
    # three leaves (flattening order a, b, c): geometric 4, 1, 1/4 —
    # constant within each leaf
    np.testing.assert_allclose(m[:12], 4.0)
    np.testing.assert_allclose(m[12:19], 1.0)
    np.testing.assert_allclose(m[19:], 0.25)
    # single-leaf tree: just head
    one = wire.TreeSpec.from_tree({"w": jnp.zeros(6)})
    np.testing.assert_array_equal(
        np.asarray(C.SigmaSchedule(head=3.0, tail=9.0).multipliers(one)),
        np.full(6, 3.0, np.float32))


# ---------------------------------------------------------------------------
# bit-exactness vs hand-scaled inputs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("codec", ["zsign(z=1,sigma=0.1)", "zsign_packed",
                                   "qsgd(s=2)", "topk(frac=0.3)", "dense"])
def test_encode_decode_equal_hand_scaled(codec):
    """sigma_sched|codec == codec applied to m*p, decoded /m — bitwise."""
    spec = wire.TreeSpec.from_tree(_tree())
    flat = spec.flatten(_tree())
    key = jax.random.PRNGKey(7)
    sched = C.Pipeline(f"sigma_sched(head=2.0,tail=0.5)|{codec}")
    plain = C.Pipeline(codec)
    m = np.asarray(sched.transforms[0].multipliers(spec))

    enc, _ = sched.encode(key, flat, None, spec=spec)
    enc_ref, _ = plain.encode(key, flat * m, None)
    # topk payloads are (values, indices) tuples — compare leafwise
    for got, want in zip(jax.tree.leaves(enc), jax.tree.leaves(enc_ref)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    stack = jax.tree.map(lambda x: jnp.stack([x, x, x]), enc)
    mask = jnp.asarray([1.0, 0.0, 1.0])
    g = sched.decode_sum(sched.aggregate(stack, mask, spec.n_coords),
                         jnp.asarray(2.0), spec=spec)
    g_ref = plain.decode_sum(plain.aggregate(stack, mask, spec.n_coords),
                             jnp.asarray(2.0))
    d = spec.n_coords
    np.testing.assert_array_equal(np.asarray(g)[:d], np.asarray(g_ref)[:d] / m)


def test_uniform_multiplier_is_effective_sigma():
    """head == tail == 2 at codec sigma 0.2 IS the plain codec at sigma
    0.1: Sign(2p + 0.2 xi) == Sign(p + 0.1 xi) coordinate for coordinate
    (same counter-based xi draw), and the debias scale divides out — the
    whole round estimate is bit-identical (power-of-two m keeps even the
    fp arithmetic exact)."""
    spec = wire.TreeSpec.from_tree(_tree())
    flat = spec.flatten(_tree())
    key = jax.random.PRNGKey(3)
    sched = C.Pipeline("sigma_sched(head=2,tail=2)|zsign(z=1,sigma=0.2)")
    plain = C.Pipeline("zsign(z=1,sigma=0.1)")
    enc, _ = sched.encode(key, flat, None, spec=spec)
    enc_ref, _ = plain.encode(key, flat, None)
    np.testing.assert_array_equal(np.asarray(enc), np.asarray(enc_ref))
    g = sched.decode_sum(sched.aggregate(enc[None], jnp.ones(1),
                                         spec.n_coords),
                         jnp.asarray(1.0), spec=spec)
    g_ref = plain.decode_sum(plain.aggregate(enc_ref[None], jnp.ones(1),
                                             spec.n_coords),
                             jnp.asarray(1.0))
    d = spec.n_coords  # the pad tail past n_coords is never unflattened
    np.testing.assert_array_equal(np.asarray(g)[:d], np.asarray(g_ref)[:d])


def test_sched_wire_format_unchanged():
    assert (C.Pipeline("sigma_sched|zsign_packed").wire_format().bits_per_coord
            == C.Pipeline("zsign_packed").wire_format().bits_per_coord == 1.0)


# ---------------------------------------------------------------------------
# engine threading: the round step supplies the TreeSpec by capability
# ---------------------------------------------------------------------------

def _round_setup(spec_str, *, n=8, cohort="vmap", seed=5):
    comp = C.Pipeline(spec_str)
    cfg = fedavg.FedConfig(n_clients=n, client_lr=0.01, server_lr=0.3)
    params = {"a": jnp.zeros((3, 4)), "b": jnp.zeros(7), "c": jnp.zeros(5)}

    def loss(p, b):
        flat = jnp.concatenate([p["a"].ravel(), p["b"], p["c"]])
        return 0.5 * jnp.sum((flat - b["y"]) ** 2)

    step = fedavg.build_round_step(loss, comp, cfg,
                                   RoundContext(cohort=cohort))
    y = jax.random.normal(jax.random.PRNGKey(seed), (1, n, 1, 24))
    st = fedavg.init_server_state(params, cfg, comp, jax.random.PRNGKey(1))
    return step, st, {"y": y}


def _run(spec_str, *, rounds=3, **kw):
    step, st, batch = _round_setup(spec_str, **kw)
    mask = jnp.ones((1, 8)).at[0, jnp.asarray([2, 5])].set(0.0)
    loss = None
    for _ in range(rounds):
        st, m = step(st, batch, mask)
        loss = float(m.loss)
    return st, loss


def test_engine_round_trains_and_plans_agree():
    ref, loss = _run("sigma_sched(head=4,tail=0.25)|zsign(z=1,sigma=0.3)")
    assert np.isfinite(loss)
    for cohort in ["stream(shard=3)", "stream(shard=8)",
                   "stream(shard=3,feed=host)"]:
        got, _ = _run("sigma_sched(head=4,tail=0.25)|zsign(z=1,sigma=0.3)",
                      cohort=cohort)
        np.testing.assert_array_equal(np.asarray(ref.params["a"]),
                                      np.asarray(got.params["a"]))
        np.testing.assert_array_equal(np.asarray(ref.params["c"]),
                                      np.asarray(got.params["c"]))


def test_engine_round_matches_manual_scaling():
    """A full engine round through sigma_sched(head=m,tail=m)|zsign at
    sigma m*s equals plain zsign at sigma s — the per-layer effective-sigma
    claim, end to end (power-of-two m: exact fp)."""
    ref, _ = _run("zsign(z=1,sigma=0.15)")
    got, _ = _run("sigma_sched(head=2,tail=2)|zsign(z=1,sigma=0.3)")
    for k in ("a", "b", "c"):
        np.testing.assert_array_equal(np.asarray(ref.params[k]),
                                      np.asarray(got.params[k]))


def test_engine_round_with_ef_composition():
    """sigma_sched|ef|zsign: the residual lives in the scaled domain and
    the round still trains identically across cohort plans."""
    spec = "sigma_sched(head=2,tail=0.5)|ef|zsign"
    ref, loss = _run(spec)
    assert np.isfinite(loss)
    assert list(ref.comp_state) == ["ef"]
    got, _ = _run(spec, cohort="stream(shard=3)")
    np.testing.assert_array_equal(np.asarray(ref.comp_state["ef"]),
                                  np.asarray(got.comp_state["ef"]))
    np.testing.assert_array_equal(np.asarray(ref.params["a"]),
                                  np.asarray(got.params["a"]))
