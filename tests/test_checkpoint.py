"""Fault tolerance: atomic checkpoints, corruption fallback, restart replay."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.core import compression, fedavg
from repro.fed.sampling import ParticipationSampler


def small_state():
    return {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
            "opt": {"m": jnp.ones(3)},
            "round": jnp.asarray(7, jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    st = small_state()
    mgr.save(7, st)
    r, got = mgr.restore_latest(jax.tree.map(lambda x: x, st))
    assert r == 7
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(st)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_retention_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for r in range(5):
        mgr.save(r, small_state())
    names = sorted(os.listdir(tmp_path))
    assert names == ["ckpt-00000003", "ckpt-00000004"]


def test_corrupt_checkpoint_falls_back(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(1, small_state())
    mgr.save(2, small_state())
    # corrupt the newest payload (torn write / bitrot)
    path = os.path.join(tmp_path, "ckpt-00000002", "arrays.npz")
    with open(path, "r+b") as f:
        f.seek(10)
        f.write(b"\xde\xad\xbe\xef")
    r, got = mgr.restore_latest(small_state())
    assert r == 1 and got is not None


def test_restart_replays_identically(tmp_path):
    """Kill-and-restart produces the same trajectory as an uninterrupted run
    (deterministic rng in state + deterministic data) — the core FT invariant."""
    comp = compression.Pipeline("zsign(z=1,sigma=0.5)")
    cfg = fedavg.FedConfig(n_clients=4, client_lr=0.05, server_lr=0.1)
    loss_fn = lambda p, b: 0.5 * jnp.sum((p["x"] - b["y"]) ** 2)
    step = jax.jit(fedavg.build_round_step(loss_fn, comp, cfg))
    y = jax.random.normal(jax.random.PRNGKey(5), (1, 4, 1, 16))
    mask = jnp.ones((1, 4))

    def fresh():
        return fedavg.init_server_state({"x": jnp.zeros(16)}, cfg, comp,
                                        jax.random.PRNGKey(9))

    # uninterrupted: 10 rounds
    st = fresh()
    for _ in range(10):
        st, _ = step(st, {"y": y}, mask)
    ref = np.asarray(st.params["x"])

    # interrupted at round 6 + restart from checkpoint
    mgr = CheckpointManager(str(tmp_path))
    st = fresh()
    for r in range(6):
        st, _ = step(st, {"y": y}, mask)
    mgr.save(6, st._asdict())
    del st  # "crash"
    template = fresh()._asdict()
    r, got = mgr.restore_latest(template)
    st = fedavg.ServerState(**got)
    assert r == 6
    for _ in range(4):
        st, _ = step(st, {"y": y}, mask)
    np.testing.assert_allclose(np.asarray(st.params["x"]), ref, rtol=1e-6)


def test_participation_sampler_straggler_and_failures():
    s = ParticipationSampler(total_clients=64, per_round=16,
                             over_provision=1.5, failure_rate=0.1, seed=0)
    masks = [s.mask((4, 16)) for _ in range(20)]
    for m in masks:
        assert m.shape == (4, 16)
        assert 1 <= m.sum() <= 16
    # randomized across rounds
    assert len({tuple(m.reshape(-1)) for m in masks}) > 1
