"""Fused client encode: counter-based in-kernel noise equivalence suite.

The client encode has four implementations that must agree:

  "reference"  dense jax.random draw + pack (the statistical oracle)
  "jnp"        fused counter-based single pass (default CPU path)
  "jnp" + encode_chunk_tiles > 0   chunked-scan variant (bounded jaxpr-level
               noise window)
  "pallas"     in-kernel counter noise (TPU; interpret mode on CPU)

Contract (see core/noise.py and compression.py docstrings):
  * the three fused paths are BIT-EXACT against each other for the same
    client key — same global element counters, same per-tile word layout,
    same f32 threshold math;
  * the fused bit [u > 1 - P_z(x/sigma)] is the inverse-CDF coupling of
    Sign(x + sigma * F_z^{-1}(u)) — identically distributed to the reference
    draw (checked against the closed-form expected sign and pdf_z);
  * no (n_clients, d) fp32 noise buffer exists: jaxpr-level for the chunked
    and pallas paths, compiled-buffer-level for the single-pass default.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compression as C
from repro.core import fedavg
from repro.core import noise as Z
from repro.core import wire
from repro.kernels.zsign import ops

TILE = C.ENCODE_TILE


def test_encode_tile_matches_kernel():
    """compression.ENCODE_TILE mirrors the kernel tile — keep in sync."""
    assert C.ENCODE_TILE == ops.TILE


def test_threefry_matches_random123_vectors():
    """The cipher structure is canonical Threefry-2x32: at 20 rounds it must
    reproduce the published Random123 known-answer vectors exactly, and the
    13-round production stream is pinned against silent drift."""
    orig = Z.THREEFRY_ROUNDS
    try:
        Z.THREEFRY_ROUNDS = 20
        for (c0, c1), (k0, k1), want in [
                ((0, 0), (0, 0), (0x6B200159, 0x99BA4EFE)),
                ((0xFFFFFFFF, 0xFFFFFFFF), (0xFFFFFFFF, 0xFFFFFFFF),
                 (0x1CB996FC, 0xBB002BE7)),
                ((0x243F6A88, 0x85A308D3), (0x13198A2E, 0x03707344),
                 (0xC4923A9C, 0x483DF7A0))]:
            y0, y1 = Z.threefry2x32(jnp.uint32(k0), jnp.uint32(k1),
                                    jnp.uint32(c0), jnp.uint32(c1))
            assert (int(y0), int(y1)) == want
    finally:
        Z.THREEFRY_ROUNDS = orig
    assert Z.THREEFRY_ROUNDS == 13  # the cited BigCrush-minimal variant
    y0, y1 = Z.threefry2x32(jnp.uint32(0), jnp.uint32(0),
                            jnp.uint32(0), jnp.uint32(0))
    # regression pin of the production 13-round stream (matches the
    # Random123 R=13 unrolling: no injection after the partial last group)
    assert (int(y0), int(y1)) == (0x9D1C5EC6, 0x8BD50731)


# ---------------------------------------------------------------------------
# bit-exactness across fused backends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("z", [1, Z.Z_INF])
@pytest.mark.parametrize("d", [64, 8192, 3 * 8192 + 17, 100_003])
@pytest.mark.parametrize("sigma", [0.3, 5.0])
def test_fused_backends_bit_exact(z, d, sigma):
    key = jax.random.PRNGKey(d + z)
    flat = jax.random.normal(jax.random.PRNGKey(0), (d,))
    got = {
        "jnp": C.fused_sign_encode_jnp(flat, key, sigma, z=z),
        "jnp_chunk1": C.fused_sign_encode_jnp(flat, key, sigma, z=z,
                                              chunk_tiles=1),
        "jnp_chunk3": C.fused_sign_encode_jnp(flat, key, sigma, z=z,
                                              chunk_tiles=3),
        "pallas": ops.zsign_encode_fused(flat, key, sigma, z=z),
    }
    n_bytes = -(-d // TILE) * TILE // 8
    for name, p in got.items():
        assert p.shape == (n_bytes,) and p.dtype == jnp.uint8, name
        np.testing.assert_array_equal(np.asarray(got["jnp"]), np.asarray(p),
                                      err_msg=name)


@pytest.mark.parametrize("name", ["zsign", "zsign_packed", "stosign"])
def test_compressor_backends_bit_exact(name):
    """Through the compressor API (incl. stosign's dynamic sigma = ||flat||),
    jnp and pallas encode backends ship identical wire bytes."""
    d = 2 * 8192 + 117
    flat = jax.random.normal(jax.random.PRNGKey(1), (d,))
    key = jax.random.PRNGKey(7)
    opts = "" if name == "stosign" else "z=1,sigma=0.4,"
    outs = {}
    for backend in ["jnp", "pallas"]:
        comp = C.Pipeline(f"{name}({opts}encode_backend={backend})")
        outs[backend], _ = comp.encode(key, flat, None)
    np.testing.assert_array_equal(np.asarray(outs["jnp"]),
                                  np.asarray(outs["pallas"]))


def test_vmapped_encode_matches_per_client():
    """Under the engine's client vmap each client gets its own counter
    stream; rows match per-client single calls exactly."""
    n, d = 5, 8192 + 13
    keys = jax.random.split(jax.random.PRNGKey(3), n)
    flats = jax.random.normal(jax.random.PRNGKey(4), (n, d))
    comp = C.Pipeline("zsign(z=1,sigma=0.5,encode_backend=jnp)")
    stacked = jax.vmap(lambda k, f: comp.encode(k, f, None)[0])(keys, flats)
    for i in range(n):
        single, _ = comp.encode(keys[i], flats[i], None)
        np.testing.assert_array_equal(np.asarray(stacked[i]),
                                      np.asarray(single))
    # distinct clients -> distinct streams
    assert np.any(np.asarray(stacked[0]) != np.asarray(stacked[1]))


def test_vmapped_pallas_encode_matches_per_client():
    """The pallas backend's custom vmap rule (grid-folded on TPU, the
    tile-scanned jnp twin in interpret mode) reproduces each client's
    unbatched byte stream bit-exactly — single- and multi-tile widths."""
    for n, d in [(5, 1024), (3, 2 * TILE + 77)]:
        keys = jax.random.split(jax.random.PRNGKey(3), n)
        flats = jax.random.normal(jax.random.PRNGKey(4), (n, d))
        comp = C.Pipeline("zsign(z=1,sigma=0.5,encode_backend=pallas)")
        stacked = jax.jit(jax.vmap(
            lambda k, f: comp.encode(k, f, None)[0]))(keys, flats)
        for i in range(n):
            single, _ = comp.encode(keys[i], flats[i], None)
            np.testing.assert_array_equal(np.asarray(stacked[i]),
                                          np.asarray(single), err_msg=str(d))


def test_vmapped_pallas_encode_cost_linear_in_clients():
    """Scaling regression (the historical vmap blowup): JAX's default
    pallas batching rule made each interpret-mode grid step rewrite the
    whole batched output, so per-client encode cost grew ~linearly with
    the vmap width (measured 50 -> 730 us/client from n=16 to n=128 at
    d=1024 — ~14x). The custom vmap rule is elementwise-linear: pin the
    per-client cost ratio n=128 / n=16 to a small factor (generous bound;
    the regression is an order of magnitude)."""
    import time

    d = 1024
    comp = C.Pipeline("zsign_packed(z=1,sigma=0.5)")

    def per_client_seconds(n):
        keys = jax.random.split(jax.random.PRNGKey(1), n)
        flats = jax.random.normal(jax.random.PRNGKey(2), (n, d))
        f = jax.jit(jax.vmap(lambda f_, k: comp.encode(k, f_, None)[0]))
        jax.block_until_ready(f(flats, keys))      # compile
        best = min(
            _timed(lambda: jax.block_until_ready(f(flats, keys)), time)
            for _ in range(5))
        return best / n

    assert per_client_seconds(128) < 4.0 * per_client_seconds(16)


def _timed(fn, time):
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def test_unknown_encode_backend_raises():
    comp = C.Pipeline("zsign(encode_backend=nope)")
    with pytest.raises(ValueError, match="unknown encode backend"):
        comp.encode(jax.random.PRNGKey(0), jnp.ones((8,)), None)


# ---------------------------------------------------------------------------
# distribution: counter noise vs pdf_z / closed-form expected sign
# ---------------------------------------------------------------------------

def test_counter_noise_z1_is_standard_normal():
    xi = np.asarray(Z.counter_noise(jax.random.PRNGKey(11), 400_000, 1),
                    np.float64)
    assert abs(xi.mean()) < 0.01
    assert abs(xi.std() - 1.0) < 0.01
    assert abs((xi ** 3).mean()) < 0.03          # symmetry
    assert abs((xi ** 4).mean() - 3.0) < 0.1     # gaussian kurtosis
    # KS distance vs the exact CDF
    s = np.sort(xi)
    cdf = 0.5 * (1.0 + np.array([math.erf(v / math.sqrt(2)) for v in
                                 s[:: len(s) // 2000]]))
    emp = np.arange(len(s))[:: len(s) // 2000] / len(s)
    assert np.max(np.abs(cdf - emp)) < 0.01


def test_counter_noise_zinf_is_uniform():
    xi = np.asarray(Z.counter_noise(jax.random.PRNGKey(12), 400_000, Z.Z_INF),
                    np.float64)
    assert xi.min() > -1.0 and xi.max() < 1.0
    assert abs(xi.mean()) < 0.01
    assert abs(xi.std() - 1.0 / math.sqrt(3)) < 0.005
    # KS vs the linear CDF
    s = np.sort(xi)
    emp = np.arange(len(s))[:: len(s) // 2000] / len(s)
    assert np.max(np.abs((s[:: len(s) // 2000] + 1) / 2 - emp)) < 0.01


@pytest.mark.parametrize("z", [1, Z.Z_INF])
def test_counter_noise_matches_pdf_z_histogram(z):
    """Histogram of the counter stream vs Definition 1's density."""
    xi = np.asarray(Z.counter_noise(jax.random.PRNGKey(13), 400_000, z))
    edges = np.linspace(-2.5, 2.5, 26)
    hist, _ = np.histogram(xi, bins=edges, density=True)
    centers = 0.5 * (edges[:-1] + edges[1:])
    want = np.asarray(Z.pdf_z(centers, z))
    # uniform's discontinuity at +-1 lands inside a bin; skip those two
    keep = np.abs(np.abs(centers) - 1.0) > 0.15 if z <= Z.Z_INF else \
        np.ones_like(centers, bool)
    np.testing.assert_allclose(hist[keep], want[keep], atol=0.02)


@pytest.mark.parametrize("z", [1, Z.Z_INF])
def test_fused_mean_sign_matches_expected_sign(z):
    """eta_z * sigma * mean(decoded signs) ~= expected_sign (Lemma 3's
    closed form) — the fused Bernoulli bit has the exact sign law of the
    additive-noise encoder."""
    sigma = 1.3
    grid = jnp.linspace(-2.0, 2.0, 32)
    reps = 8192
    flat = jnp.repeat(grid, reps)                # 32 * 8192 coords
    payload = C.fused_sign_encode_jnp(flat, jax.random.PRNGKey(5), sigma, z=z)
    signs = np.asarray(wire.unpack_signs(payload), np.float64)[: flat.size]
    mean_sign = signs.reshape(32, reps).mean(axis=1)
    got = Z.eta_z(z) * sigma * mean_sign
    want = np.asarray(Z.expected_sign(grid, sigma, z))
    np.testing.assert_allclose(got, want, atol=0.05)


@pytest.mark.parametrize("z", [1, Z.Z_INF])
def test_threshold_is_inverse_cdf_coupling(z):
    """The fused bit [u > 1 - P_z(x/s)] equals Sign(x + s * F_z^{-1}(u))
    computed from the SAME counter stream, up to f32 boundary rounding."""
    d = 100_000
    key = jax.random.PRNGKey(21)
    x = jax.random.normal(jax.random.PRNGKey(22), (d,))
    sigma = 0.7
    payload = C.fused_sign_encode_jnp(x, key, sigma, z=z)
    got = np.asarray(wire.unpack_signs(payload))[:d] > 0
    xi = Z.counter_noise(key, d, z)
    want = np.asarray(x + sigma * xi >= 0)
    assert (got == want).mean() > 0.9999


def test_stosign_fused_mean_sign_matches_clip():
    """stosign = z=inf with sigma = ||flat||: mean sign of many independent
    encodings approaches clip(x / ||x||, -1, 1) (exactly unbiased regime)."""
    reps, vals = 4096, jnp.asarray([-0.5, -0.1, 0.0, 0.2, 0.6])
    flat = jnp.repeat(vals, reps)
    comp = C.Pipeline("stosign(encode_backend=jnp)")
    payload, _ = comp.encode(jax.random.PRNGKey(9), flat, None)
    signs = np.asarray(wire.unpack_signs(payload), np.float64)[: flat.size]
    mean_sign = signs.reshape(5, reps).mean(axis=1)
    nrm = float(jnp.linalg.norm(flat))
    want = np.clip(np.asarray(vals) / nrm, -1.0, 1.0)
    np.testing.assert_allclose(mean_sign, want, atol=0.03)


# ---------------------------------------------------------------------------
# reference backend and fallbacks
# ---------------------------------------------------------------------------

def test_reference_backend_is_dense_draw():
    """encode_backend="reference" pins the pre-fused semantics exactly:
    pack_flat(flat + sigma * sample_z_noise(key))."""
    d, z, sigma = 1000, 1, 0.6
    key = jax.random.PRNGKey(2)
    flat = jax.random.normal(jax.random.PRNGKey(1), (d,))
    comp = C.Pipeline(f"zsign(z={z},sigma={sigma},"
                      f"encode_backend=reference)")
    got, _ = comp.encode(key, flat, None)
    want = wire.pack_flat(flat + sigma * Z.sample_z_noise(key, (d,), z))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_finite_z_falls_back_to_dense():
    """z = 2 has no counter transform: every backend routes to the dense
    draw and produces the reference bytes for the same key."""
    d = 500
    key = jax.random.PRNGKey(4)
    flat = jax.random.normal(jax.random.PRNGKey(3), (d,))
    ref, _ = C.Pipeline("zsign(z=2,sigma=0.5,"
                        "encode_backend=reference)").encode(key, flat, None)
    for backend in ["auto", "jnp"]:
        got, _ = C.Pipeline(f"zsign(z=2,sigma=0.5,"
                            f"encode_backend={backend})").encode(
                                key, flat, None)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@pytest.mark.parametrize("name", ["zsign", "zsign_packed"])
@pytest.mark.parametrize("backend", ["jnp", "pallas", "reference"])
def test_sigma_zero_is_noise_free_on_all_backends(name, backend):
    """Vanilla-SignSGD mode (sigma == 0): every backend produces the exact
    noise-free signs."""
    d = 8192 + 5
    flat = jax.random.normal(jax.random.PRNGKey(6), (d,))
    comp = C.Pipeline(f"{name}(z=1,sigma=0.0,encode_backend={backend})")
    payload, _ = comp.encode(jax.random.PRNGKey(0), flat, None)
    signs = np.asarray(wire.unpack_signs(payload))[:d]
    want = np.where(np.asarray(flat) >= 0, 1, -1)
    np.testing.assert_array_equal(signs, want)


def _walk_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            inner = getattr(v, "jaxpr", None)
            if inner is not None:
                yield from _walk_eqns(inner)
            if isinstance(v, (list, tuple)):
                for vv in v:
                    inner = getattr(vv, "jaxpr", None)
                    if inner is not None:
                        yield from _walk_eqns(inner)


def test_sigma_zero_packed_draws_no_rng():
    """Regression (satellite): PackedZSign's dense path used to draw (and
    discard) a full noise buffer when sigma == 0 — no PRNG primitive may
    appear in any sigma == 0 encode jaxpr."""
    d = 8192
    flat = jnp.ones((d,))
    for backend in ["reference", "jnp", "pallas"]:
        comp = C.Pipeline(f"zsign_packed(z=1,sigma=0.0,"
                          f"encode_backend={backend})")
        jaxpr = jax.make_jaxpr(
            lambda k, f: comp.encode(k, f, None)[0])(
                jax.random.PRNGKey(0), flat)
        for eqn in _walk_eqns(jaxpr.jaxpr):
            assert "threefry" not in eqn.primitive.name, (backend, eqn)
            assert "erf" not in eqn.primitive.name, (backend, eqn)


# ---------------------------------------------------------------------------
# no (n_clients, d) fp32 noise buffer
# ---------------------------------------------------------------------------

# structural data movement of the input buffer itself (padding x to the
# tile boundary, reshapes) is not noise — only COMPUTED f32 values count.
_STRUCTURAL = {"pad", "reshape", "squeeze", "transpose", "broadcast_in_dim",
               "convert_element_type", "slice", "dynamic_slice",
               "dynamic_update_slice", "concatenate", "copy",
               # transparent containers: their bodies are walked instead
               "pjit", "closed_call", "custom_jvp_call", "custom_vjp_call"}


def _max_f32_outvar_bytes(jaxpr):
    worst = 0
    for eqn in _walk_eqns(jaxpr):
        if eqn.primitive.name in _STRUCTURAL:
            continue
        for var in eqn.outvars:
            aval = getattr(var, "aval", None)
            if aval is None or not hasattr(aval, "shape"):
                continue
            if aval.dtype == jnp.float32:
                n = 1
                for s in aval.shape:
                    n *= int(s)
                worst = max(worst, 4 * n)
    return worst


@pytest.mark.parametrize("setup", [
    ("pallas", 0), ("jnp", 2),
])
def test_no_dense_noise_buffer_in_encode_jaxpr(setup):
    """Jaxpr scan: the chunked-jnp and pallas fused encodes never produce an
    fp32 intermediate anywhere near (n_clients, d) — the largest fp32 outvar
    in the whole client fan-out stays bounded by the chunk window. The
    reference dense draw (sanity check) produces the full stacked buffer."""
    backend, chunk = setup
    n, d = 16, 8 * TILE + 100
    keys = jax.random.split(jax.random.PRNGKey(0), n)
    flats = jnp.zeros((n, d))
    comp = C.Pipeline(f"zsign(z=1,sigma=0.5,encode_backend={backend},"
                      f"encode_chunk_tiles={chunk})")
    fan_out = jax.vmap(lambda k, f: comp.encode(k, f, None)[0])
    worst = _max_f32_outvar_bytes(jax.make_jaxpr(fan_out)(keys, flats).jaxpr)
    stacked_noise_bytes = 4 * n * d
    limit = 4 * n * max(chunk, 1) * TILE  # the chunk window (pallas: 0 eqns)
    assert worst < stacked_noise_bytes / 4, (backend, worst)
    assert worst <= limit, (backend, worst)

    ref = C.Pipeline("zsign(z=1,sigma=0.5,encode_backend=reference)")
    worst_ref = _max_f32_outvar_bytes(
        jax.make_jaxpr(jax.vmap(lambda k, f: ref.encode(k, f, None)[0]))(
            keys, flats).jaxpr)
    assert worst_ref >= stacked_noise_bytes  # the pathology, still visible


def test_no_dense_noise_buffer_in_compiled_single_pass():
    """Compiled-buffer scan for the single-pass jnp default: XLA fuses the
    whole counter->threshold->bitpack chain into the uint8 payload, so the
    compiled round allocates ~zero temp where the reference dense draw
    allocates the full (n_clients, d) fp32 noise surface (and more)."""
    n, d = 8, 16 * TILE + 1
    keys = jax.random.split(jax.random.PRNGKey(0), n)
    flats = jnp.zeros((n, d))
    temps = {}
    for backend in ["jnp", "reference"]:
        comp = C.Pipeline(f"zsign(z=1,sigma=0.5,"
                          f"encode_backend={backend})")
        fan_out = jax.jit(jax.vmap(lambda k, f: comp.encode(k, f, None)[0]))
        mem = fan_out.lower(keys, flats).compile().memory_analysis()
        temps[backend] = mem.temp_size_in_bytes
    stacked_noise_bytes = 4 * n * d
    assert temps["jnp"] < stacked_noise_bytes / 4, temps
    assert temps["reference"] >= stacked_noise_bytes, temps


# ---------------------------------------------------------------------------
# compressed-domain group scan
# ---------------------------------------------------------------------------

def _consensus(comp, groups, n, d, seed=0):
    y = jax.random.normal(jax.random.PRNGKey(seed), (1, groups * n, 1, d))
    loss_fn = lambda p, b: 0.5 * jnp.sum((p["x"] - b["y"]) ** 2)
    cfg = fedavg.FedConfig(n_clients=n, client_groups=groups,
                           client_lr=0.01, server_lr=0.3)
    step = jax.jit(fedavg.build_round_step(loss_fn, comp, cfg))
    st = fedavg.init_server_state({"x": jnp.zeros(d)}, cfg, comp,
                                  jax.random.PRNGKey(1))
    return step, st, y.reshape(groups, n, 1, d)


def test_stacks_group_payloads_dispatch():
    assert C.Pipeline("zsign").stacks_group_payloads()
    assert C.Pipeline("ef|zsign").stacks_group_payloads()
    assert C.Pipeline("ef|topk").stacks_group_payloads()
    assert not C.Pipeline("identity").stacks_group_payloads()
    assert not C.Pipeline("qsgd").stacks_group_payloads()
    assert not C.Pipeline("dp(noise=1.0)|dense").stacks_group_payloads()


@pytest.mark.parametrize("mask_on", [True, False])
def test_group_scan_bit_identical_to_vmap_path(mask_on):
    """8 clients as 2x4 (payload-stacking scan) vs 1x8 (vmap): the fused
    encode streams and the single 8-client sign-reduce are the same
    computation, so params must be BIT-identical (0/1 mask -> integer sums),
    including under partial participation."""
    d = 80
    outs = {}
    for groups, n in [(1, 8), (2, 4)]:
        comp = C.Pipeline("zsign(z=1,sigma=1.0)")
        step, st, y = _consensus(comp, groups, n, d, seed=5)
        mask = jnp.ones((groups, n))
        if mask_on:
            mask = mask.reshape(1, 8).at[0, 2].set(0.0).at[0, 7].set(
                0.0).reshape(groups, n)
        st = st._replace(rng=jax.random.PRNGKey(42))
        for _ in range(5):
            st, m = step(st, {"y": y}, mask)
        outs[groups] = np.asarray(st.params["x"])
    np.testing.assert_array_equal(outs[1], outs[2])


def test_group_stack_aggregate_equals_per_group_sum():
    """One sign_reduce over the (G*N, n_bytes) stack == per-group reduces
    summed: exact for 0/1 masks, f32-rounding-close for EF scale weights."""
    G, N, n_bytes = 3, 8, 1024
    rng = np.random.RandomState(0)
    packed = jnp.asarray(rng.randint(0, 256, (G, N, n_bytes)), jnp.uint8)
    mask = jnp.asarray(rng.randint(0, 2, (G, N)).astype(np.float32))
    one = C.sign_reduce(packed.reshape(G * N, n_bytes), mask.reshape(-1),
                        "jnp")
    per = sum(C.sign_reduce(packed[g], mask[g], "jnp") for g in range(G))
    np.testing.assert_array_equal(np.asarray(one), np.asarray(per))
    scales = jnp.asarray(rng.rand(G, N).astype(np.float32))
    one_w = C.sign_reduce(packed.reshape(G * N, n_bytes),
                          (mask * scales).reshape(-1), "jnp")
    per_w = sum(C.sign_reduce(packed[g], mask[g] * scales[g], "jnp")
                for g in range(G))
    np.testing.assert_allclose(np.asarray(one_w), np.asarray(per_w),
                               rtol=1e-5, atol=1e-5)


def test_group_scan_emits_payload_stack_not_dense_partials():
    """Jaxpr of the G>1 round for a sign compressor: the scan's carry/ys hold
    uint8 payloads; no fp32 array of (G*N, d) or per-group dense decode
    appears before the single final aggregate."""
    d = 2 * TILE
    comp = C.Pipeline("zsign(z=1,sigma=0.5,encode_chunk_tiles=1)")
    G, n = 4, 4
    cfg = fedavg.FedConfig(n_clients=n, client_groups=G, client_lr=0.01,
                           server_lr=0.3)
    loss_fn = lambda p, b: 0.5 * jnp.sum((p["x"] - b["y"]) ** 2)
    step = fedavg.build_round_step(loss_fn, comp, cfg)
    st = fedavg.init_server_state({"x": jnp.zeros(d)}, cfg, comp,
                                  jax.random.PRNGKey(1))
    batch = {"y": jnp.zeros((G, n, 1, d))}
    jaxpr = jax.make_jaxpr(step)(st, batch, jnp.ones((G, n)))
    # find the scan over groups and check its outputs are u8 payload stacks
    scans = [e for e in _walk_eqns(jaxpr.jaxpr) if e.primitive.name == "scan"]
    assert scans, "group loop must lower to lax.scan"
    group_scan = max(scans, key=lambda e: len(e.outvars))
    u8_outs = [v for v in group_scan.outvars
               if getattr(v.aval, "dtype", None) == jnp.uint8]
    assert u8_outs, "group scan must emit the stacked uint8 payloads"
    for v in group_scan.outvars:
        aval = v.aval
        if aval.dtype == jnp.float32 and aval.ndim >= 1:
            n_el = int(np.prod(aval.shape))
            assert n_el < d, f"dense f32 group partial in scan outputs: {aval}"


# ---------------------------------------------------------------------------
# static participation-mask dispatch
# ---------------------------------------------------------------------------

def test_weights_are_mask_dispatches_popcount():
    """build_round_step(weights_are_mask=True) routes the jnp sign-reduce
    through wire.unpack_sum_mask (population_count in the jaxpr); the
    default keeps the LUT path."""
    n, n_bytes = 8, 256
    payload = jnp.zeros((n, n_bytes), jnp.uint8)
    mask = jnp.ones((n,))
    for flag, want in [(True, True), (False, False)]:
        comp = C.Pipeline(f"zsign(agg_backend=jnp,"
                          f"weights_are_mask={flag})")
        jaxpr = jax.make_jaxpr(
            lambda p, m: comp.aggregate(p, m, 8 * n_bytes))(payload, mask)
        has_pc = any(e.primitive.name == "population_count"
                     for e in _walk_eqns(jaxpr.jaxpr))
        assert has_pc == want, (flag, has_pc)


def test_weights_are_mask_identical_results():
    """The popcount specialization is bit-identical for real 0/1 masks,
    end-to-end through the engine."""
    d = 120
    outs = {}
    for flag in [False, True]:
        comp = C.Pipeline("zsign(z=1,sigma=1.0)")
        loss_fn = lambda p, b: 0.5 * jnp.sum((p["x"] - b["y"]) ** 2)
        cfg = fedavg.FedConfig(n_clients=6, client_lr=0.01, server_lr=0.3)
        step = jax.jit(fedavg.build_round_step(loss_fn, comp, cfg,
                                               weights_are_mask=flag))
        st = fedavg.init_server_state({"x": jnp.zeros(d)}, cfg, comp,
                                      jax.random.PRNGKey(1))
        y = jax.random.normal(jax.random.PRNGKey(2), (1, 6, 1, d))
        mask = jnp.ones((1, 6)).at[0, 3].set(0.0)
        for _ in range(4):
            st, _ = step(st, {"y": y}, mask)
        outs[flag] = np.asarray(st.params["x"])
    np.testing.assert_array_equal(outs[False], outs[True])


def test_e1_fast_client_path_matches_legacy():
    """The E == 1 gradient shortcut and the legacy scan+subtract client path
    (the benchmark's dense-baseline engine) agree to f32 rounding — the
    only difference is the (gamma*g)/gamma round-trip the fast path skips."""
    d, n = 96, 6
    comp = C.Pipeline("identity")
    loss_fn = lambda p, b: 0.5 * jnp.sum((p["x"] - b["y"]) ** 2)
    cfg = fedavg.FedConfig(n_clients=n, client_lr=0.01, server_lr=0.5)
    y = jax.random.normal(jax.random.PRNGKey(2), (1, n, 1, d))
    mask = jnp.ones((1, n))
    outs = {}
    for legacy in [False, True]:
        step = jax.jit(fedavg.build_round_step(loss_fn, comp, cfg,
                                               legacy_client_path=legacy))
        st = fedavg.init_server_state({"x": jnp.zeros(d)}, cfg, comp,
                                      jax.random.PRNGKey(1))
        for _ in range(5):
            st, m = step(st, {"y": y}, mask)
        outs[legacy] = np.asarray(st.params["x"])
    np.testing.assert_allclose(outs[False], outs[True], rtol=2e-5, atol=1e-6)


def test_efsign_has_no_mask_flag():
    """EF weights are mask * scale — never a pure membership mask; the
    engine must not be able to flip a flag on it."""
    assert "weights_are_mask" not in {
        f.name for f in __import__("dataclasses").fields(
            C.Pipeline("ef|zsign"))}
