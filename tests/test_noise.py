"""Properties of the z-distribution noise (paper Definition 1, Lemma 1/2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypo_compat import given, settings, st

from repro.core import noise as Z


@pytest.mark.parametrize("z", [1, 2, 4, Z.Z_INF])
def test_noise_symmetric_zero_mean(z):
    key = jax.random.PRNGKey(0)
    x = Z.sample_z_noise(key, (200_000,), z)
    assert abs(float(jnp.mean(x))) < 0.02
    # symmetry: mean of odd powers ~ 0
    assert abs(float(jnp.mean(x ** 3))) < 0.05


def test_z1_is_gaussian():
    x = Z.sample_z_noise(jax.random.PRNGKey(1), (200_000,), 1)
    assert abs(float(jnp.std(x)) - 1.0) < 0.02


def test_zinf_is_uniform():
    x = Z.sample_z_noise(jax.random.PRNGKey(2), (100_000,), Z.Z_INF)
    assert float(jnp.min(x)) >= -1.0 and float(jnp.max(x)) <= 1.0
    assert abs(float(jnp.std(x)) - (1.0 / np.sqrt(3.0))) < 0.01


def test_eta_z_limits():
    # eta_1 = sqrt(2) Gamma(3/2) = sqrt(pi/2); eta_inf -> 1
    assert abs(Z.eta_z(1) - np.sqrt(np.pi / 2)) < 1e-9
    assert abs(Z.eta_z(1000) - 1.0) < 1e-2
    assert Z.eta_z(Z.Z_INF) == 1.0


@pytest.mark.parametrize("z", [1, 2, Z.Z_INF])
def test_asymptotic_unbiasedness(z):
    """Lemma 1: eta_z * sigma * E[Sign(x + sigma xi)] -> x for large sigma.

    Monte-Carlo estimate of the debiased sign vs the input."""
    key = jax.random.PRNGKey(3)
    x = jnp.linspace(-1.0, 1.0, 41)
    sigma = 20.0
    n_mc = 40_000
    xi = Z.sample_z_noise(key, (n_mc, x.size), z)
    signs = jnp.where(x[None] + sigma * xi >= 0, 1.0, -1.0)
    est = Z.eta_z(z) * sigma * jnp.mean(signs, axis=0)
    # MC std of the estimate ~ eta*sigma/sqrt(n) ~ 0.12
    np.testing.assert_allclose(np.asarray(est), np.asarray(x), atol=0.45)


@pytest.mark.parametrize("z", [1, 3])
def test_bias_bound_lemma1(z):
    """|eta_z sigma E[Sign(x+sigma xi)] - x| <= |x|^{2z+1} / (2(2z+1) sigma^{2z})
    via the closed-form expectation."""
    for sigma in (1.0, 2.0, 5.0):
        x = jnp.linspace(-0.9 * sigma, 0.9 * sigma, 31)
        est = Z.expected_sign(x, sigma, z) * Z.eta_z(z) / Z.eta_z(z)
        # expected_sign returns sigma*Psi_z(x/sigma) which IS the
        # (eta_z sigma E[Sign])-value; check Lemma 3 bound elementwise
        bound = jnp.abs(x) ** (2 * z + 1) / (2 * (2 * z + 1) * sigma ** (2 * z))
        err = jnp.abs(est - x)
        assert bool(jnp.all(err <= bound + 1e-5))


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=1, max_value=6),
       st.floats(min_value=0.1, max_value=50.0, allow_nan=False))
def test_expected_sign_monotone_and_bounded(z, sigma):
    """Psi_z is odd, monotone, and |sigma*Psi_z(x/sigma)| <= |x| (Lemma 3)."""
    x = jnp.linspace(-3 * sigma, 3 * sigma, 25)
    est = Z.expected_sign(x, sigma, z)
    assert bool(jnp.all(jnp.abs(est) <= jnp.abs(x) + 1e-3))
    assert bool(jnp.all(jnp.diff(est) >= -1e-4))
    np.testing.assert_allclose(np.asarray(est), -np.asarray(est[::-1]),
                               atol=1e-4)
