"""Substrate coverage: data pipeline, optimizers, sharding plans, hints."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypo_compat import given, settings, st

from repro.data import synthetic
from repro.optim.optimizers import make_optimizer


# -- data -------------------------------------------------------------------

def test_token_stream_deterministic_and_seekable():
    s = synthetic.TokenStream(vocab=101, seed=3)
    a = s.round_batch(7, (1, 2, 2, 3), 16)
    b = s.round_batch(7, (1, 2, 2, 3), 16)
    c = s.round_batch(8, (1, 2, 2, 3), 16)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(a), np.asarray(c))
    assert a.shape == (1, 2, 2, 3, 16)
    assert int(a.max()) < 101 and int(a.min()) >= 0


def test_label_partition_is_disjoint_cover():
    _, y = synthetic.gaussian_mixture_task(n_classes=10, n_per_class=20)
    parts = synthetic.label_partition(y, 10)
    all_idx = np.concatenate(parts)
    assert len(all_idx) == len(np.unique(all_idx)) == y.shape[0]
    # each client sees exactly one label
    for p in parts:
        assert len(np.unique(np.asarray(y)[p])) == 1


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=2, max_value=16),
       st.floats(min_value=0.05, max_value=10.0))
def test_dirichlet_partition_cover(n_clients, alpha):
    _, y = synthetic.gaussian_mixture_task(n_classes=6, n_per_class=30)
    parts = synthetic.dirichlet_partition(y, n_clients, alpha=alpha)
    all_idx = np.concatenate([p for p in parts if len(p)])
    assert len(all_idx) == len(np.unique(all_idx)) == y.shape[0]


# -- optimizers ---------------------------------------------------------------

@pytest.mark.parametrize("name,kw", [("sgd", {}), ("momentum", {"beta": 0.9}),
                                     ("adam", {})])
def test_optimizers_descend_quadratic(name, kw):
    opt = make_optimizer(name, lr=0.1, **kw)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}  # d/dw ||w||^2
        params, state = opt.update(grads, state, params)
    assert float(jnp.linalg.norm(params["w"])) < 1e-2


# -- sharding plans ------------------------------------------------------------

def test_plans_cover_global_batch():
    from repro.configs.common import SHAPES, get_arch, list_archs
    from repro.launch.sharding import make_plan

    class M:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    class M2:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}

    for arch_id in list_archs():
        arch = get_arch(arch_id)
        for mesh in (M(), M2()):
            plan = make_plan(arch, SHAPES["train_4k"], mesh)
            total = (plan.micro * plan.n_clients * plan.client_groups
                     * plan.local_steps)
            assert total == SHAPES["train_4k"].global_batch, (arch_id, plan)


def test_param_specs_shard_big_dims():
    import jax
    from repro.configs.common import SHAPES, get_arch
    from repro.launch import sharding as SH
    from repro.models.api import build_model

    class M:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    arch = get_arch("qwen2_0_5b")  # vocab divisible by 16 => embed sharded
    plan = SH.make_plan(arch, SHAPES["train_4k"], M())
    shapes = jax.eval_shape(build_model(arch.model).init, jax.random.PRNGKey(0))
    specs = SH.param_specs(shapes, M(), plan)
    # embed sharded on vocab; attention mats sharded somewhere
    assert specs["embed"][0] is not None
    flat = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda s: hasattr(s, "index"))
    sharded = sum(1 for s in flat if any(e is not None for e in s))
    assert sharded >= len(flat) // 2


# -- hints off-mesh are no-ops -------------------------------------------------

def test_hints_noop_without_mesh():
    from repro.launch import hints as H
    x = jnp.ones((4, 32, 8))
    assert H.seq_shard(x) is x
    assert H.gather_seq(x) is x
    assert H.seq_shard_count() == 1
    lp = {"w": jnp.ones((8, 8))}
    assert H.fsdp_params(lp, skip=())["w"] is lp["w"]
