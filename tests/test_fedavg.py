"""Federated engine: the paper's §4.1 consensus experiments as tests.

Includes the headline divergence counterexample (vanilla SignSGD stalls at a
non-stationary point; z-SignSGD with enough noise converges) — i.e. the
paper's central claim, reproduced.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compression, fedavg


def consensus_setup(comp, *, d=50, n=10, E=1, glr=0.01, slr=1.0, sigma0=0.0,
                    seed=0, groups=1, server_opt="sgd"):
    key = jax.random.PRNGKey(seed)
    y = jax.random.normal(key, (groups, n, d))
    cfg = fedavg.FedConfig(n_clients=n, client_groups=groups, local_steps=E,
                           client_lr=glr, server_lr=slr, server_opt=server_opt)
    loss_fn = lambda p, b: 0.5 * jnp.sum((p["x"] - b["y"]) ** 2)
    step = jax.jit(fedavg.build_round_step(loss_fn, comp, cfg))
    params = {"x": jnp.zeros(d)}
    state = fedavg.init_server_state(params, cfg, comp, jax.random.PRNGKey(1),
                                     sigma0)
    batch = {"y": jnp.broadcast_to(y[:, :, None], (groups, n, E, d))}
    mask = jnp.ones((groups, n))
    opt = y.reshape(-1, d).mean(0)
    return step, state, batch, mask, opt


def run(step, state, batch, mask, T):
    for _ in range(T):
        state, m = step(state, batch, mask)
    return state, m


def test_uncompressed_fedavg_converges_exactly():
    step, st, b, m, opt = consensus_setup(compression.Pipeline("identity"))
    st, _ = run(step, st, b, m, 1500)
    assert float(jnp.linalg.norm(st.params["x"] - opt)) < 1e-3


def test_signsgd_counterexample_stalls():
    """Paper §1: two clients with opposing gradients — vanilla sign never
    moves once the sign votes cancel; z-sign with noise escapes."""
    # f_1 = (x-A)^2, f_2 = (x+A)^2, x0 = A/2: signs cancel => no progress.
    A = 1.0
    y = jnp.asarray([[[A], [-A]]])  # (1, 2, 1)
    loss_fn = lambda p, b: jnp.sum((p["x"] - b["y"]) ** 2)
    cfg = fedavg.FedConfig(n_clients=2, client_lr=0.05, server_lr=0.2)

    def simulate(comp, T=800):
        step = jax.jit(fedavg.build_round_step(loss_fn, comp, cfg))
        params = {"x": jnp.full((1,), A / 2)}
        st = fedavg.init_server_state(params, cfg, comp, jax.random.PRNGKey(0))
        batch = {"y": y[:, :, None]}
        for _ in range(T):
            st, _ = step(st, batch, jnp.ones((1, 2)))
        return float(st.params["x"][0])

    x_sign = simulate(compression.Pipeline("zsign(sigma=0.0)"))
    x_zsign = simulate(compression.Pipeline("zsign(z=1,sigma=2.0)"))
    assert abs(x_sign - A / 2) < 1e-6          # stuck exactly at x0
    assert abs(x_zsign) < abs(x_sign - 0.0)    # moved toward optimum 0
    assert abs(x_zsign) < 0.25


@pytest.mark.parametrize("z", [1, 0])
def test_zsign_consensus_converges(z):
    comp = compression.Pipeline(f"zsign(z={z},sigma=2.0)")
    step, st, b, m, opt = consensus_setup(comp, slr=0.05)
    st, _ = run(step, st, b, m, 2000)
    assert float(jnp.linalg.norm(st.params["x"] - opt)) < 1.5


def test_multiple_local_steps_reduce_rounds():
    """FedAvg benefit (paper Fig. 5): E=4 reaches a target loss in fewer
    rounds than E=1 at the same client lr."""
    def dist_after(E, T):
        comp = compression.Pipeline("zsign(z=1,sigma=1.0)")
        step, st, b, m, opt = consensus_setup(comp, E=E, glr=0.05, slr=0.05)
        st, _ = run(step, st, b, m, T)
        return float(jnp.linalg.norm(st.params["x"] - opt))

    assert dist_after(4, 150) < dist_after(1, 150)


def test_sequential_groups_match_parallel():
    """groups x parallel decomposition is exact for linear decoders."""
    comp = compression.Pipeline("identity")
    step1, st1, b1, m1, opt = consensus_setup(comp, n=8, groups=1, seed=3)
    # same 8 clients as 2 groups of 4
    cfg2 = fedavg.FedConfig(n_clients=4, client_groups=2, client_lr=0.01,
                            server_lr=1.0)
    y = b1["y"].reshape(2, 4, 1, 50)
    loss_fn = lambda p, b: 0.5 * jnp.sum((p["x"] - b["y"]) ** 2)
    step2 = jax.jit(fedavg.build_round_step(loss_fn, comp, cfg2))
    st2 = fedavg.init_server_state({"x": jnp.zeros(50)}, cfg2, comp,
                                   jax.random.PRNGKey(1))
    st2 = st2._replace(rng=st1.rng)
    for _ in range(20):
        st1, _ = step1(st1, b1, m1)
        st2, _ = step2(st2, {"y": y}, jnp.ones((2, 4)))
    np.testing.assert_allclose(np.asarray(st1.params["x"]),
                               np.asarray(st2.params["x"]), rtol=1e-5)


def test_partial_participation_mask():
    """Dead clients excluded; aggregation renormalized by live count."""
    comp = compression.Pipeline("identity")
    step, st, b, m, opt = consensus_setup(comp, n=10)
    mask = m.at[0, 5:].set(0.0)   # only clients 0-4 live
    st, metrics = step(st, b, mask)
    assert float(metrics.participation) == 5.0
    # decoded estimate equals mean over live clients only
    live_opt = b["y"][0, :5, 0].mean(0)
    got = np.asarray(st.params["x"]) / 0.01  # one step of lr * mean-grad
    want = np.asarray(live_opt)              # grad at 0 is -(y_i); update=+mean y
    np.testing.assert_allclose(got, want, rtol=1e-4)


def test_dp_clipping_bounds_update():
    comp = compression.Pipeline("identity")
    cfg = fedavg.FedConfig(n_clients=2, client_lr=0.01, server_lr=1.0,
                           dp_clip=0.5)
    loss_fn = lambda p, b: jnp.sum((p["x"] - b["y"]) ** 2) * 100.0
    step = jax.jit(fedavg.build_round_step(loss_fn, comp, cfg))
    st = fedavg.init_server_state({"x": jnp.zeros(4)}, cfg, comp,
                                  jax.random.PRNGKey(0))
    batch = {"y": jnp.ones((1, 2, 1, 4)) * 100}
    st2, _ = step(st, batch, jnp.ones((1, 2)))
    # per-client pseudo-grad clipped to norm 0.5 => update norm <= lr*0.5
    assert float(jnp.linalg.norm(st2.params["x"])) <= 0.01 * 0.5 + 1e-6


@pytest.mark.parametrize("spec", ["ef|zsign", "ef|topk(frac=0.25)"])
@pytest.mark.parametrize("groups", [1, 2])
def test_dead_clients_keep_residual_exactly(spec, groups):
    """Participation-masked aggregation with STATEFUL compressors: a dead
    client's flat residual buffer must be bit-identical across the round,
    on both the vmap (groups=1) and the lax.scan (groups=2) paths."""
    comp = compression.Pipeline(spec)
    step, st, b, m, _ = consensus_setup(comp, d=16, n=4, groups=groups,
                                        seed=11)
    # one full-participation round so residuals become nonzero
    st, _ = step(st, b, m)
    assert st.comp_state["ef"].shape == (groups, 4, 16)
    assert float(jnp.sum(jnp.abs(st.comp_state["ef"]))) > 0.0
    before = np.asarray(st.comp_state["ef"]).copy()
    # kill client 1 in every group, client 3 in the last group
    mask = m.at[:, 1].set(0.0).at[groups - 1, 3].set(0.0)
    st2, metrics = step(st, b, mask)
    after = np.asarray(st2.comp_state["ef"])
    assert float(metrics.participation) == float(jnp.sum(mask))
    for g in range(groups):
        np.testing.assert_array_equal(after[g, 1], before[g, 1])
        live = [i for i in range(4)
                if not (i == 1 or (g == groups - 1 and i == 3))]
        for i in live:
            assert np.any(after[g, i] != before[g, i]), \
                f"live client ({g},{i}) residual did not update"
    np.testing.assert_array_equal(after[groups - 1, 3], before[groups - 1, 3])


def test_stateful_masked_groups_match_vmap_path():
    """8 clients as 1x8 (vmap) vs 2x4 (scan) with a stateful compressor and
    partial participation: identical params and identical residuals."""
    comp = compression.Pipeline("ef|zsign")
    cfg1 = fedavg.FedConfig(n_clients=8, client_groups=1, client_lr=0.01,
                            server_lr=0.5)
    cfg2 = fedavg.FedConfig(n_clients=4, client_groups=2, client_lr=0.01,
                            server_lr=0.5)
    d = 12
    y = jax.random.normal(jax.random.PRNGKey(2), (1, 8, 1, d))
    loss_fn = lambda p, b: 0.5 * jnp.sum((p["x"] - b["y"]) ** 2)
    step1 = jax.jit(fedavg.build_round_step(loss_fn, comp, cfg1))
    step2 = jax.jit(fedavg.build_round_step(loss_fn, comp, cfg2))
    st1 = fedavg.init_server_state({"x": jnp.zeros(d)}, cfg1, comp,
                                   jax.random.PRNGKey(1))
    st2 = fedavg.init_server_state({"x": jnp.zeros(d)}, cfg2, comp,
                                   jax.random.PRNGKey(1))
    mask = jnp.asarray([[1., 0., 1., 1., 0., 1., 1., 1.]])
    for _ in range(10):
        st1, _ = step1(st1, {"y": y}, mask)
        st2, _ = step2(st2, {"y": y.reshape(2, 4, 1, d)},
                       mask.reshape(2, 4))
    # group-split equivalence is exact only up to f32 association: the scan
    # path adds two 4-client partial sums while the vmap path reduces all 8
    # clients at once, and efsign's weights are per-client fp32 scales
    np.testing.assert_allclose(np.asarray(st1.params["x"]),
                               np.asarray(st2.params["x"]), rtol=5e-5)
    np.testing.assert_allclose(
        np.asarray(st1.comp_state["ef"]).reshape(8, -1),
        np.asarray(st2.comp_state["ef"]).reshape(8, -1), rtol=5e-5)


def test_uplink_bits_zsign_vs_identity():
    za = compression.Pipeline("zsign(z=1,sigma=1.0)")
    ia = compression.Pipeline("identity")
    s1, st1, b, m, _ = consensus_setup(za)
    s2, st2, *_ = consensus_setup(ia)
    _, m1 = s1(st1, b, m)
    _, m2 = s2(st2, b, m)
    assert float(m2.uplink_bits) / float(m1.uplink_bits) == 32.0
