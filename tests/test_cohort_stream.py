"""Streaming massive-cohort engine: stream-vs-vmap equivalence suite.

The round driver has two execution plans over the same round math
(core/fedavg.py): ``vmap`` (one vmap over all parallel clients) and
``stream`` (shard-sized slices under a lax.scan folding into ONE wire
accumulator). Contract:

  * per-client PRNG keys derive from the GLOBAL client index
    (noise.client_keys), so randomness is invariant to the shard partition;
  * 0/1 participation masks: the two plans are BIT-identical for any shard
    size (integer sign sums / dyadic scatter sums associate exactly);
  * fp32 aggregation weights (EF per-client scales): bit-identical when the
    shard size is a multiple of wire.SIGN_REDUCE_CLIENT_BLK (the fold
    continues the same blocked accumulation order), f32-rounding-close
    otherwise;
  * the streaming jaxpr never materializes an (n_total, d) f32 buffer or a
    full-cohort uint8 payload stack — peak wire memory is O(shard * d / 8);
  * ``auto`` (and a bare ``stream``) gate small rounds back to the vmap
    plan; an explicit ``stream(shard=K)`` always streams.

Multi-device (``stream(devices=D)``, shard_map over a 1-D ``clients`` mesh):

  * 0/1 masks: D in {1, 2, 4, 8} is BIT-identical to the vmap plan and the
    single-device stream at any shard size — integer sign sums stay exact
    under the cross-device psum, and counter-based keys are placement-
    invariant;
  * fp32 EF scale weights: residuals (per-client, never summed across
    devices) are bit-identical per round; params are f32-close (the psum
    meets the per-device partial sums in a different association order than
    the sequential fold). ``ef|zsign(scale=none)`` has 0/1 weights, so it is
    fully exact multi-round at any D;
  * the ONLY cross-device collective in the round jaxpr is an O(d) fp32
    psum of the wire accumulator (plus the scalar loss psum) — never a
    payload stack, never per-client data (the jaxpr pin below).

These run under XLA_FLAGS=--xla_force_host_platform_device_count=8 (the CI
multi-device smoke job); with fewer visible devices they skip.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compression as C
from repro.core import fedavg, wire
from repro.core import noise as Z
from repro.core.context import (COHORT_DEVICES_AUTO, STREAM_AUTO_MIN_ELEMS,
                                STREAM_DEFAULT_SHARD, STREAM_SHARD_AUTO,
                                STREAM_SHARD_MAX, STREAM_SHARD_MIN,
                                CohortPolicy, RoundContext)
from repro.fed.sampling import CohortSampler

_DC = jax.device_count()


def _devices(d):
    """Parametrize a device count, skipping when the host shows fewer
    devices (run under XLA_FLAGS=--xla_force_host_platform_device_count=8
    to unskip — see the CI multi-device smoke job)."""
    return pytest.param(d, marks=pytest.mark.skipif(
        _DC < d, reason=f"needs {d} devices (have {_DC}); set "
        f"XLA_FLAGS=--xla_force_host_platform_device_count={d}"))


# ---------------------------------------------------------------------------
# policy parsing + the auto-gate
# ---------------------------------------------------------------------------

def test_cohort_policy_parse():
    assert CohortPolicy.parse("auto") == CohortPolicy("auto")
    assert CohortPolicy.parse("vmap") == CohortPolicy("vmap")
    assert CohortPolicy.parse("stream") == CohortPolicy("stream")
    pol = CohortPolicy.parse("stream(shard=16,unroll=2)")
    assert (pol.mode, pol.shard, pol.unroll) == ("stream", 16, 2)
    # idempotent on an already-parsed policy
    assert CohortPolicy.parse(pol) is pol
    # shard=0 is VALID ("engine default"), so it still auto-gates
    assert CohortPolicy.parse("stream(shard=0)").shard == 0
    # the device axis and the shard/feed sentinels
    assert CohortPolicy.parse("stream(shard=auto)").shard == STREAM_SHARD_AUTO
    assert CohortPolicy.parse("stream(devices=4)").devices == 4
    assert CohortPolicy.parse(
        "stream(devices=auto)").devices == COHORT_DEVICES_AUTO
    pol = CohortPolicy.parse("stream(shard=auto,devices=auto,unroll=2)")
    assert pol == CohortPolicy("stream", STREAM_SHARD_AUTO, 2,
                               COHORT_DEVICES_AUTO, "device")
    assert CohortPolicy.parse("stream(feed=host)").feed == "host"
    assert CohortPolicy.parse("stream(shard=8,feed=device)").feed == "device"
    for bad in ["nope", "stream(shard=a)", "vmap(shard=2)",
                "stream(shard=2,unroll=0)", "stream(frac=2)",
                "stream(unroll=auto)",       # auto is shard/devices-only
                "vmap(devices=2)",           # device axis is stream-only
                "auto(feed=host)",
                "stream(feed=nope)",
                "stream(devices=2,feed=host)"]:  # host feed is single-device
        with pytest.raises(ValueError):
            CohortPolicy.parse(bad)
    with pytest.raises(ValueError):
        RoundContext(cohort="stream(shard=-1)")
    with pytest.raises(ValueError):
        RoundContext(cohort="stream(devices=-2)")


def test_resolve_cohort_gating():
    big = STREAM_AUTO_MIN_ELEMS  # elems threshold: total * n_coords
    plan = lambda shard, unroll=1, devices=1, feed="device": \
        fedavg.CohortPlan("stream", shard, unroll, devices, feed)
    # explicit vmap never streams
    assert fedavg.resolve_cohort("vmap", 1 << 20, 1 << 20) == fedavg.VMAP_PLAN
    # auto below the threshold keeps the vmap plan
    assert fedavg.resolve_cohort("auto", 8, 100) == fedavg.VMAP_PLAN
    assert fedavg.resolve_cohort("stream", 8, 100) == fedavg.VMAP_PLAN
    # auto above the threshold streams at the memory-budget shard size
    d = big // 1024
    assert fedavg.resolve_cohort("auto", 4096, d) == \
        plan(fedavg.auto_shard_size(d))
    # explicit shard forces streaming below the threshold
    assert fedavg.resolve_cohort("stream(shard=4)", 8, 100) == plan(4)
    # shard clamps to the cohort; forced single-shard still streams
    assert fedavg.resolve_cohort("stream(shard=64)", 10, 100) == plan(10)
    # unroll rides along
    assert fedavg.resolve_cohort("stream(shard=4,unroll=3)", 8, 100) == \
        plan(4, unroll=3)
    # shard=auto forces streaming at the auto-tuned (clamped) size
    assert fedavg.resolve_cohort("stream(shard=auto)", 8, 100) == plan(8)
    # feed=host forces streaming and survives into the plan
    assert fedavg.resolve_cohort("stream(feed=host)", 8, 100) == \
        plan(8, feed="host")
    # auto where one (auto-sized) shard covers the whole cohort -> vmap
    assert fedavg.resolve_cohort("auto", 4, 1 << 22) == fedavg.VMAP_PLAN
    # devices clamp to the shard count (no all-padding devices) and
    # validate against the visible device count
    got = fedavg.resolve_cohort("stream(shard=4,devices=auto)", 8, 100)
    assert got == plan(4, devices=min(jax.device_count(), 2))
    with pytest.raises(ValueError, match="device"):
        fedavg.resolve_cohort(
            f"stream(shard=4,devices={jax.device_count() + 1})", 8, 100)
    # a launcher plan that shards the client axis over its own mesh
    # (spmd_axes, e.g. dryrun's 16x16 production cell) pre-empts streaming:
    # auto keeps the vmap plan even far above the element threshold (the
    # shard scan would serialize the mesh-parallel axis and trigger
    # involuntary remats), and a FORCED stream there is a config conflict
    assert fedavg.resolve_cohort("auto", 4096, d,
                                 spmd_axes=("data",)) == fedavg.VMAP_PLAN
    assert fedavg.resolve_cohort("stream", 4096, d,
                                 spmd_axes=("data",)) == fedavg.VMAP_PLAN
    with pytest.raises(ValueError, match="client axis"):
        fedavg.resolve_cohort("stream(shard=4)", 4096, d,
                              spmd_axes=("data",))
    with pytest.raises(ValueError, match="client axis"):
        fedavg.resolve_cohort("stream(feed=host)", 4096, d,
                              spmd_axes=("data",))


def test_auto_shard_size():
    blk = wire.SIGN_REDUCE_CLIENT_BLK
    # no model info -> the static default
    assert fedavg.auto_shard_size(0) == STREAM_DEFAULT_SHARD
    # tiny models clamp high, huge models clamp low
    assert fedavg.auto_shard_size(100) == STREAM_SHARD_MAX
    assert fedavg.auto_shard_size(1 << 28) == STREAM_SHARD_MIN
    # the benchmark model (~1.3M coords) fits 48 clients in the 256 MB
    # budget: 48 * (4*d + d/8) bytes ~ 250 MB
    assert fedavg.auto_shard_size(1_323_018) == 48
    # always a SIGN_REDUCE_CLIENT_BLK multiple inside the clamp band, so
    # the fp32-weighted fold stays blocked identically across shards
    for d in [1 << 18, 1 << 20, 3_000_000, 10_000_001]:
        k = fedavg.auto_shard_size(d)
        assert k % blk == 0 or k in (STREAM_SHARD_MIN, STREAM_SHARD_MAX)
        assert STREAM_SHARD_MIN <= k <= STREAM_SHARD_MAX


def test_client_keys_invariant_to_partition():
    """client_keys is a counter derivation: any shard partition concatenates
    to the same per-client key rows."""
    key = jax.random.PRNGKey(3)
    whole = np.asarray(Z.client_keys(key, 0, 12))
    parts = np.concatenate([np.asarray(Z.client_keys(key, 0, 5)),
                            np.asarray(Z.client_keys(key, 5, 7))])
    np.testing.assert_array_equal(whole, parts)
    # distinct clients -> distinct keys
    assert len({tuple(r) for r in whole.tolist()}) == 12


# ---------------------------------------------------------------------------
# wire fold API: aggregate(..., acc=...) continues one concatenated reduce
# ---------------------------------------------------------------------------

def test_wire_fold_mask_exact_any_split():
    rng = np.random.RandomState(0)
    packed = jnp.asarray(rng.randint(0, 256, (20, 64)), jnp.uint8)
    mask = jnp.asarray(rng.randint(0, 2, 20).astype(np.float32))
    want = np.asarray(wire.unpack_sum(packed, mask))
    for split in [1, 7, 8, 13]:
        acc = None
        for lo in range(0, 20, split):
            acc = wire.unpack_sum(packed[lo:lo + split], mask[lo:lo + split],
                                  acc=acc)
        np.testing.assert_array_equal(np.asarray(acc), want, err_msg=str(split))
        acc = None
        for lo in range(0, 20, split):
            acc = wire.unpack_sum_mask(packed[lo:lo + split],
                                       mask[lo:lo + split], acc=acc)
        np.testing.assert_array_equal(np.asarray(acc), want, err_msg=str(split))


def test_wire_fold_fp32_weights_exact_at_client_blk_multiples():
    blk = wire.SIGN_REDUCE_CLIENT_BLK
    rng = np.random.RandomState(1)
    packed = jnp.asarray(rng.randint(0, 256, (4 * blk, 128)), jnp.uint8)
    w = jnp.asarray(rng.rand(4 * blk).astype(np.float32))
    want = np.asarray(wire.unpack_sum(packed, w))
    acc = None
    for lo in range(0, 4 * blk, blk):
        acc = wire.unpack_sum(packed[lo:lo + blk], w[lo:lo + blk], acc=acc)
    np.testing.assert_array_equal(np.asarray(acc), want)


@pytest.mark.parametrize("split", [1, 3, 5, 7, 8, 11, 20])
def test_wire_fold_fp32_weights_exact_at_any_split(split):
    """The structured SignFoldAcc carry makes the fp32-weighted fold
    bit-identical to one concatenated reduce at ANY partition — the
    pending-row buffer preserves the full call's 8-client LUT blocking, so
    off-blk splits no longer re-associate the sums. Bytes-level equality
    (tobytes) also pins signed zeros."""
    n, n_bytes = 20, 96
    rng = np.random.RandomState(3)
    packed = jnp.asarray(rng.randint(0, 256, (n, n_bytes)), jnp.uint8)
    w = jnp.asarray(rng.rand(n).astype(np.float32))
    want = np.asarray(wire.unpack_sum(packed, w))
    acc = wire.sign_fold_init(n_bytes)
    for lo in range(0, n, split):
        acc = wire.unpack_sum(packed[lo:lo + split], w[lo:lo + split],
                              acc=acc)
    got = np.asarray(wire.sign_fold_finalize(acc))
    assert got.tobytes() == want.tobytes()


def test_scatter_and_dense_fold():
    rng = np.random.RandomState(2)
    vals = jnp.asarray(rng.randint(-8, 8, (6, 3)).astype(np.float32))
    idx = jnp.asarray(rng.randint(0, 10, (6, 3)))
    m = jnp.asarray(rng.randint(0, 2, 6).astype(np.float32))
    want = np.asarray(wire.scatter_sum_coo(vals, idx, m, 10))
    got = wire.scatter_sum_coo(vals[3:], idx[3:], m[3:], 10,
                               acc=wire.scatter_sum_coo(vals[:3], idx[:3],
                                                        m[:3], 10))
    np.testing.assert_array_equal(np.asarray(got), want)
    dense = jnp.asarray(rng.randint(-4, 4, (6, 10)).astype(np.float32))
    want = np.asarray(wire.dense_masked_sum(dense, m))
    got = wire.dense_masked_sum(dense[3:], m[3:],
                                acc=wire.dense_masked_sum(dense[:3], m[:3]))
    np.testing.assert_array_equal(np.asarray(got), want)


# ---------------------------------------------------------------------------
# end-to-end: streaming rounds == vmap rounds
# ---------------------------------------------------------------------------

def _run_rounds(spec, cohort, *, n=16, d=96, rounds=4, seed=5,
                mask=None, glr=0.01, slr=0.3, integer_targets=False,
                jit=True):
    comp = C.Pipeline(spec)
    cfg = fedavg.FedConfig(n_clients=n, client_lr=glr, server_lr=slr)
    ctx = RoundContext(cohort=cohort)
    loss_fn = lambda p, b: 0.5 * jnp.sum((p["x"] - b["y"]) ** 2)
    step = fedavg.build_round_step(loss_fn, comp, cfg, ctx)
    if jit:  # feed=host returns a Python-loop driver that must not be jitted
        step = jax.jit(step)
    y = jax.random.normal(jax.random.PRNGKey(seed), (1, n, 1, d))
    if integer_targets:
        y = jnp.round(y * 4.0)  # dyadic targets keep every sum associative
    st = fedavg.init_server_state({"x": jnp.zeros(d)}, cfg, comp,
                                  jax.random.PRNGKey(1))
    mask = jnp.ones((1, n)) if mask is None else mask
    for _ in range(rounds):
        st, m = step(st, {"y": y}, mask)
    return st, m


# 8 of 16 live -> n_live is a power of two, so the post-aggregate mean stays
# dyadic for the integer-target (top-k) case
_MASK16 = jnp.ones((1, 16)).at[0, jnp.asarray([1, 4, 5, 9, 11, 12, 13, 15])
                               ].set(0.0)


@pytest.mark.parametrize("shard", [1, 7, 64])
def test_stream_bit_identical_zsign_packed(shard):
    """0/1 masks -> integer sign sums: streaming at ANY shard size is
    bit-identical to the vmap plan, dead clients included."""
    ref, mref = _run_rounds("zsign_packed(z=1,sigma=0.7)", "vmap",
                            mask=_MASK16)
    got, mgot = _run_rounds("zsign_packed(z=1,sigma=0.7)",
                            f"stream(shard={shard})", mask=_MASK16)
    np.testing.assert_array_equal(np.asarray(ref.params["x"]),
                                  np.asarray(got.params["x"]))
    assert float(mref.loss) == float(mgot.loss)
    assert float(mref.participation) == float(mgot.participation) == 8.0


def test_stream_bit_identical_ef_zsign_at_blk_multiple():
    """EF per-client fp32 scale weights: shard == SIGN_REDUCE_CLIENT_BLK
    continues the same blocked accumulation order -> bit-identical params
    AND residuals."""
    blk = wire.SIGN_REDUCE_CLIENT_BLK
    ref, _ = _run_rounds("ef|zsign", "vmap", mask=_MASK16)
    got, _ = _run_rounds("ef|zsign", f"stream(shard={blk})", mask=_MASK16)
    np.testing.assert_array_equal(np.asarray(ref.params["x"]),
                                  np.asarray(got.params["x"]))
    np.testing.assert_array_equal(np.asarray(ref.comp_state["ef"]),
                                  np.asarray(got.comp_state["ef"]))


@pytest.mark.parametrize("shard", [1, 7, 64])
def test_stream_bit_identical_ef_zsign_any_shard(shard):
    """EF per-client fp32 scale weights at OFF-blk shard sizes: the
    SignFoldAcc carry keeps the streamed fold in the full call's 8-client
    block order, so streaming is bit-identical to vmap — params AND
    residuals — at every shard size, not just blk multiples."""
    ref, _ = _run_rounds("ef|zsign", "vmap", mask=_MASK16)
    got, _ = _run_rounds("ef|zsign", f"stream(shard={shard})", mask=_MASK16)
    np.testing.assert_array_equal(np.asarray(ref.params["x"]),
                                  np.asarray(got.params["x"]))
    np.testing.assert_array_equal(np.asarray(ref.comp_state["ef"]),
                                  np.asarray(got.comp_state["ef"]))


@pytest.mark.parametrize("shard", [1, 7, 64])
def test_stream_bit_identical_topk_dyadic(shard):
    """top-k COO scatter sums: dyadic client values (integer targets, dyadic
    lrs, power-of-two live count) make every addition exact, so the
    shard-by-shard scatter fold is bit-identical to the one-shot scatter —
    EF residuals included."""
    kw = dict(mask=_MASK16, glr=0.5, slr=0.5, integer_targets=True)
    ref, _ = _run_rounds("ef|topk(frac=0.25)", "vmap", **kw)
    got, _ = _run_rounds("ef|topk(frac=0.25)", f"stream(shard={shard})", **kw)
    np.testing.assert_array_equal(np.asarray(ref.params["x"]),
                                  np.asarray(got.params["x"]))
    np.testing.assert_array_equal(np.asarray(ref.comp_state["ef"]),
                                  np.asarray(got.comp_state["ef"]))


# ---------------------------------------------------------------------------
# multi-device: shard_map rounds == vmap == single-device stream
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("devices", [_devices(2), _devices(4), _devices(8)])
@pytest.mark.parametrize("shard", [3, 8])
def test_shard_map_bit_identical_zsign_packed(devices, shard):
    """0/1 masks -> integer sign sums stay exact under the cross-device
    psum, and counter-based keys are placement-invariant: D devices are
    bit-identical to the vmap plan AND the D=1 stream at any shard size,
    multi-round, dead clients included."""
    spec = "zsign_packed(z=1,sigma=0.7)"
    ref, mref = _run_rounds(spec, "vmap", mask=_MASK16)
    one, _ = _run_rounds(spec, f"stream(shard={shard})", mask=_MASK16)
    got, mgot = _run_rounds(spec, f"stream(shard={shard},devices={devices})",
                            mask=_MASK16)
    np.testing.assert_array_equal(np.asarray(ref.params["x"]),
                                  np.asarray(got.params["x"]))
    np.testing.assert_array_equal(np.asarray(one.params["x"]),
                                  np.asarray(got.params["x"]))
    # the loss METRIC is a plain fp32 sum of per-client losses (not part of
    # the integer-exact wire fold), so the psum may re-associate it by an ulp
    assert float(mref.loss) == pytest.approx(float(mgot.loss), rel=1e-6)
    assert float(mref.participation) == float(mgot.participation) == 8.0


@pytest.mark.parametrize("devices", [_devices(2), _devices(4), _devices(8)])
def test_shard_map_ef_zsign_one_round(devices):
    """EF fp32 scale weights across devices: the per-client residuals are
    never summed across devices, so ONE round from the same state leaves
    them bit-identical to the vmap plan (dead clients keep theirs exactly);
    the params go through the psum (a different fp32 association order than
    the sequential fold) and are f32-rounding-close."""
    kw = dict(mask=_MASK16, rounds=1)
    ref, _ = _run_rounds("ef|zsign", "vmap", **kw)
    got, _ = _run_rounds("ef|zsign", f"stream(shard=8,devices={devices})",
                         **kw)
    np.testing.assert_array_equal(np.asarray(ref.comp_state["ef"]),
                                  np.asarray(got.comp_state["ef"]))
    np.testing.assert_allclose(np.asarray(ref.params["x"]),
                               np.asarray(got.params["x"]), rtol=5e-5,
                               atol=1e-7)


@pytest.mark.parametrize("devices", [_devices(2), _devices(4), _devices(8)])
def test_shard_map_ef_zsign_scale_none_exact_multiround(devices):
    """ef|zsign(scale=none) aggregates with pure 0/1 weights (no per-client
    fp32 scale), so the sharded-residual EF round is FULLY bit-identical
    across device counts over multiple rounds — params and residuals."""
    spec = "ef|zsign(scale=none)"
    ref, _ = _run_rounds(spec, "vmap", mask=_MASK16)
    for cohort in ["stream(shard=8)", f"stream(shard=8,devices={devices})",
                   f"stream(shard=3,devices={devices})"]:
        got, _ = _run_rounds(spec, cohort, mask=_MASK16)
        np.testing.assert_array_equal(np.asarray(ref.params["x"]),
                                      np.asarray(got.params["x"]),
                                      err_msg=cohort)
        np.testing.assert_array_equal(np.asarray(ref.comp_state["ef"]),
                                      np.asarray(got.comp_state["ef"]),
                                      err_msg=cohort)


@pytest.mark.parametrize("devices", [_devices(2), _devices(4), _devices(8)])
def test_shard_map_topk_dyadic_exact(devices):
    """top-k COO scatter across devices: dyadic client values (integer
    targets, dyadic lrs, power-of-two live count) keep every addition —
    including the psum — exact, so shard_map rounds are bit-identical to
    vmap, EF residuals included."""
    kw = dict(mask=_MASK16, glr=0.5, slr=0.5, integer_targets=True)
    ref, _ = _run_rounds("ef|topk(frac=0.25)", "vmap", **kw)
    got, _ = _run_rounds("ef|topk(frac=0.25)",
                         f"stream(shard=3,devices={devices})", **kw)
    np.testing.assert_array_equal(np.asarray(ref.params["x"]),
                                  np.asarray(got.params["x"]))
    np.testing.assert_array_equal(np.asarray(ref.comp_state["ef"]),
                                  np.asarray(got.comp_state["ef"]))


def test_host_feed_bit_identical_to_device_stream():
    """stream(feed=host): the double-buffered host feeder slices the same
    shards with the same global-index keys and the same left-fold order, so
    the host round is bit-identical to the device-fed stream — residual
    state included. Both run un-jitted here: the host driver cannot be
    jitted, and whole-round jit may fuse the decode/update tail into
    different (ulp-level) fp32 arithmetic than the eager tail, which is a
    jit-vs-eager artifact orthogonal to the shard feeding."""
    spec = "ef|zsign(scale=none)"
    ref, mref = _run_rounds(spec, "stream(shard=5)", mask=_MASK16, rounds=3,
                            jit=False)
    got, mgot = _run_rounds(spec, "stream(shard=5,feed=host)", mask=_MASK16,
                            rounds=3, jit=False)
    np.testing.assert_array_equal(np.asarray(ref.params["x"]),
                                  np.asarray(got.params["x"]))
    np.testing.assert_array_equal(np.asarray(ref.comp_state["ef"]),
                                  np.asarray(got.comp_state["ef"]))
    assert float(mref.loss) == float(mgot.loss)
    assert int(mgot.shard_clients) == 5


def test_round_metrics_record_shard():
    """RoundMetrics.shard_clients: the resolved (possibly auto-tuned) shard
    size rides out with every streamed round; the vmap plan records 0."""
    _, m = _run_rounds("zsign(z=1,sigma=0.5)", "stream(shard=7)", rounds=1)
    assert int(m.shard_clients) == 7
    _, m = _run_rounds("zsign(z=1,sigma=0.5)", "vmap", rounds=1)
    assert int(m.shard_clients) == 0
    # shard=auto resolves through auto_shard_size (d=96 clamps to the max,
    # then to the cohort size)
    _, m = _run_rounds("zsign(z=1,sigma=0.5)", "stream(shard=auto)", rounds=1)
    assert int(m.shard_clients) == 16


def test_round_metrics_shard_clients_dtype_stable_when_buffered():
    """shard_clients is a DEVICE int32 scalar on every driver path (the
    field default, the jitted stream/vmap rounds, and the eager host-fed
    round), so a buffered metrics window stacks to int32 — a host np.int32
    leaking in would silently re-derive the stacked dtype."""
    default = fedavg.RoundMetrics(*([jnp.zeros(())] * 4)).shard_clients
    assert isinstance(default, jax.Array) and default.dtype == jnp.int32
    buffered = []
    for cohort, jit in [("stream(shard=7)", True), ("vmap", True),
                        ("stream(shard=5,feed=host)", False)]:
        _, m = _run_rounds("zsign(z=1,sigma=0.5)", cohort, rounds=1, jit=jit)
        assert isinstance(m.shard_clients, jax.Array), cohort
        assert m.shard_clients.dtype == jnp.int32, cohort
        buffered.append(m.shard_clients)
    stacked = jnp.stack(buffered + [default])
    assert stacked.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(stacked), [7, 0, 5, 0])


@pytest.mark.parametrize("devices", [_devices(2)])
def test_shard_map_groups_flatten_to_cohort(devices):
    """client_groups > 1 under the device axis: the (G, N) cohort flattens
    to G*N slots before the mesh partition, matching the flat-group run."""
    d = 48
    y = jax.random.normal(jax.random.PRNGKey(11), (2, 4, 1, d))
    loss_fn = lambda p, b: 0.5 * jnp.sum((p["x"] - b["y"]) ** 2)
    outs = {}
    for groups, n in [(2, 4), (1, 8)]:
        comp = C.Pipeline("zsign(z=1,sigma=0.5)")
        cfg = fedavg.FedConfig(n_clients=n, client_groups=groups,
                               client_lr=0.01, server_lr=0.3)
        step = jax.jit(fedavg.build_round_step(
            loss_fn, comp, cfg,
            RoundContext(cohort=f"stream(shard=3,devices={devices})")))
        st = fedavg.init_server_state({"x": jnp.zeros(d)}, cfg, comp,
                                      jax.random.PRNGKey(1))
        st = st._replace(rng=jax.random.PRNGKey(42))
        for _ in range(3):
            st, _ = step(st, {"y": y.reshape(groups, n, 1, d)},
                         jnp.ones((groups, n)))
        outs[groups] = np.asarray(st.params["x"])
    np.testing.assert_array_equal(outs[2], outs[1])


@pytest.mark.parametrize("shard", [1, 7, 64])
def test_shard_size_invariance(shard):
    """Streaming results do not depend on the shard size (counter-based
    keys + associative integer aggregation)."""
    base, _ = _run_rounds("zsign_packed(z=1,sigma=0.7)", "stream(shard=4)",
                          mask=_MASK16)
    got, _ = _run_rounds("zsign_packed(z=1,sigma=0.7)",
                         f"stream(shard={shard})", mask=_MASK16)
    np.testing.assert_array_equal(np.asarray(base.params["x"]),
                                  np.asarray(got.params["x"]))


def test_stream_dead_clients_keep_residual_and_padding_is_inert():
    """A cohort that does not divide the shard (10 clients, shard 4): padded
    slots contribute nothing, dead clients keep residuals bit-exactly, live
    clients update — same as the vmap plan."""
    n, d = 10, 24
    mask0 = jnp.ones((1, n))
    mask = mask0.at[0, 2].set(0.0).at[0, 9].set(0.0)
    outs = {}
    for cohort in ["vmap", "stream(shard=4)"]:
        comp = C.Pipeline("ef|zsign")
        cfg = fedavg.FedConfig(n_clients=n, client_lr=0.01, server_lr=0.3)
        loss_fn = lambda p, b: 0.5 * jnp.sum((p["x"] - b["y"]) ** 2)
        step = jax.jit(fedavg.build_round_step(loss_fn, comp, cfg,
                                               RoundContext(cohort=cohort)))
        y = jax.random.normal(jax.random.PRNGKey(7), (1, n, 1, d))
        st = fedavg.init_server_state({"x": jnp.zeros(d)}, cfg, comp,
                                      jax.random.PRNGKey(1))
        st, _ = step(st, {"y": y}, mask0)       # all-live: residuals nonzero
        before = np.asarray(st.comp_state["ef"]).copy()
        st, m = step(st, {"y": y}, mask)        # kill clients 2 and 9
        assert st.comp_state["ef"].shape == (1, n, d)
        assert float(m.participation) == n - 2
        after = np.asarray(st.comp_state["ef"])
        np.testing.assert_array_equal(after[0, 2], before[0, 2])
        np.testing.assert_array_equal(after[0, 9], before[0, 9])
        for i in range(n):
            if i not in (2, 9):
                assert np.any(after[0, i] != before[0, i]), i
        outs[cohort] = after
    # shard 4 streams 10 clients as 3 shards (2 padded slots); the
    # SignFoldAcc carry keeps the off-blk fp32 scale-weighted fold in full
    # call order -> bit-identical residuals across plans, padding included
    np.testing.assert_array_equal(outs["vmap"], outs["stream(shard=4)"])


def test_stream_groups_flatten_to_cohort():
    """client_groups > 1 under streaming: the (G, N) cohort flattens to
    G*N slots and matches the same clients run as one flat group."""
    d = 48
    y = jax.random.normal(jax.random.PRNGKey(11), (2, 4, 1, d))
    loss_fn = lambda p, b: 0.5 * jnp.sum((p["x"] - b["y"]) ** 2)
    outs = {}
    for groups, n in [(2, 4), (1, 8)]:
        comp = C.Pipeline("zsign(z=1,sigma=0.5)")
        cfg = fedavg.FedConfig(n_clients=n, client_groups=groups,
                               client_lr=0.01, server_lr=0.3)
        step = jax.jit(fedavg.build_round_step(
            loss_fn, comp, cfg, RoundContext(cohort="stream(shard=3)")))
        st = fedavg.init_server_state({"x": jnp.zeros(d)}, cfg, comp,
                                      jax.random.PRNGKey(1))
        st = st._replace(rng=jax.random.PRNGKey(42))
        for _ in range(3):
            st, _ = step(st, {"y": y.reshape(groups, n, 1, d)},
                         jnp.ones((groups, n)))
        outs[groups] = np.asarray(st.params["x"])
    np.testing.assert_array_equal(outs[2], outs[1])


# ---------------------------------------------------------------------------
# memory pins: no full-cohort buffers on the streaming plan
# ---------------------------------------------------------------------------

def _walk_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for vv in (v if isinstance(v, (list, tuple)) else (v,)):
                # ClosedJaxpr carries .jaxpr; shard_map's param is a RAW
                # Jaxpr (has .eqns directly) — recurse into both
                inner = getattr(vv, "jaxpr", vv)
                if hasattr(inner, "eqns"):
                    yield from _walk_eqns(inner)


def _stream_round_jaxpr(n_total, shard, d):
    """A streaming round whose batch leaves are tiny per client, so any
    (n_total, d)-sized array in the jaxpr is a genuine full-cohort gradient
    or payload stack, never input data."""
    comp = C.Pipeline("zsign_packed(z=1,sigma=0.5)")
    cfg = fedavg.FedConfig(n_clients=n_total, client_lr=0.01, server_lr=0.3)
    # d model coords driven by a scalar per-client target
    loss_fn = lambda p, b: 0.5 * jnp.sum((p["x"] - b["y"]) ** 2)
    step = fedavg.build_round_step(
        loss_fn, comp, cfg, RoundContext(cohort=f"stream(shard={shard})"))
    st = fedavg.init_server_state({"x": jnp.zeros(d)}, cfg, comp,
                                  jax.random.PRNGKey(1))
    batch = {"y": jnp.zeros((1, n_total, 1, 1))}
    return jax.make_jaxpr(step)(st, batch, jnp.ones((1, n_total)))


def test_stream_jaxpr_has_no_full_cohort_buffers():
    n_total, shard = 64, 8
    d = 2 * C.ENCODE_TILE              # 16384 coords, 2048 wire bytes
    n_bytes = d // 8
    jaxpr = _stream_round_jaxpr(n_total, shard, d)
    scans = [e for e in _walk_eqns(jaxpr.jaxpr)
             if e.primitive.name == "scan"]
    assert scans, "streaming must lower to lax.scan"
    for eqn in _walk_eqns(jaxpr.jaxpr):
        for var in list(eqn.outvars) + list(eqn.invars):
            aval = getattr(var, "aval", None)
            if aval is None or not hasattr(aval, "shape"):
                continue
            shape = tuple(aval.shape)
            if aval.dtype == jnp.float32 and shape[-2:] == (n_total, d):
                raise AssertionError(
                    f"full-cohort (n_total, d) f32 buffer in streaming "
                    f"jaxpr: {eqn}")
            if aval.dtype == jnp.uint8 and len(shape) >= 2 and \
                    shape[-2] == n_total and shape[-1] >= n_bytes:
                raise AssertionError(
                    f"full-cohort uint8 payload stack in streaming "
                    f"jaxpr: {eqn}")


_COLLECTIVES = frozenset({
    "psum", "all_gather", "all_to_all", "ppermute", "pmin", "pmax",
    "reduce_scatter", "pgather", "pbroadcast", "all_gather_invariant"})


@pytest.mark.parametrize("devices", [_devices(2), _devices(4)])
def test_shard_map_only_collective_is_od_psum(devices):
    """The cross-device reduce stays in the compressed-sum domain: the ONLY
    collectives in a stream(devices=D) round jaxpr are fp32 psums of O(d)
    (the wire accumulator) and O(1) (the loss scalar). No all_gather /
    all_to_all / ppermute, no uint8 payload stack and no per-client tensor
    ever crosses the interconnect, so per-device traffic is independent of
    the cohort size."""
    n_total, shard = 32, 4
    d = 2 * C.ENCODE_TILE
    comp = C.Pipeline("zsign_packed(z=1,sigma=0.5)")
    cfg = fedavg.FedConfig(n_clients=n_total, client_lr=0.01, server_lr=0.3)
    loss_fn = lambda p, b: 0.5 * jnp.sum((p["x"] - b["y"]) ** 2)
    step = fedavg.build_round_step(
        loss_fn, comp, cfg,
        RoundContext(cohort=f"stream(shard={shard},devices={devices})"))
    st = fedavg.init_server_state({"x": jnp.zeros(d)}, cfg, comp,
                                  jax.random.PRNGKey(1))
    jaxpr = jax.make_jaxpr(step)(st, {"y": jnp.zeros((1, n_total, 1, 1))},
                                 jnp.ones((1, n_total)))
    eqns = list(_walk_eqns(jaxpr.jaxpr))
    assert any(e.primitive.name == "shard_map" for e in eqns)
    colls = [e for e in eqns if e.primitive.name in _COLLECTIVES]
    assert colls, "the device fold must end in a psum"
    for eqn in colls:
        assert eqn.primitive.name == "psum", eqn
        for var in list(eqn.invars) + list(eqn.outvars):
            aval = var.aval
            assert aval.dtype == jnp.float32, eqn
            assert np.prod(aval.shape, dtype=int) <= d, eqn
    jaxpr = None
    for unroll in [1, 2]:
        comp = C.Pipeline("zsign(z=1,sigma=0.5)")
        cfg = fedavg.FedConfig(n_clients=8, client_lr=0.01, server_lr=0.3)
        loss_fn = lambda p, b: 0.5 * jnp.sum((p["x"] - b["y"]) ** 2)
        step = fedavg.build_round_step(
            loss_fn, comp, cfg,
            RoundContext(cohort=f"stream(shard=2,unroll={unroll})"))
        st = fedavg.init_server_state({"x": jnp.zeros(16)}, cfg, comp,
                                      jax.random.PRNGKey(1))
        jaxpr = jax.make_jaxpr(step)(st, {"y": jnp.zeros((1, 8, 1, 16))},
                                     jnp.ones((1, 8)))
        scans = [e for e in _walk_eqns(jaxpr.jaxpr)
                 if e.primitive.name == "scan"]
        assert scans
        assert any(e.params.get("unroll") == unroll for e in scans), unroll


def test_auto_small_round_compiles_without_scan():
    """cohort=auto (and bare stream) below the element threshold keep the
    scan-free vmap plan — no lax.scan in the round jaxpr at E == 1."""
    for cohort in ["auto", "stream"]:
        comp = C.Pipeline("zsign(z=1,sigma=0.5)")
        cfg = fedavg.FedConfig(n_clients=8, client_lr=0.01, server_lr=0.3)
        loss_fn = lambda p, b: 0.5 * jnp.sum((p["x"] - b["y"]) ** 2)
        step = fedavg.build_round_step(loss_fn, comp, cfg,
                                       RoundContext(cohort=cohort))
        st = fedavg.init_server_state({"x": jnp.zeros(32)}, cfg, comp,
                                      jax.random.PRNGKey(1))
        jaxpr = jax.make_jaxpr(step)(st, {"y": jnp.zeros((1, 8, 1, 32))},
                                     jnp.ones((1, 8)))
        assert not [e for e in _walk_eqns(jaxpr.jaxpr)
                    if e.primitive.name == "scan"], cohort


# ---------------------------------------------------------------------------
# massive-cohort sampling (fed/sampling.py CohortSampler)
# ---------------------------------------------------------------------------

def test_cohort_sampler_uniform_tier():
    s = CohortSampler(total_clients=100_000, per_round=100, seed=0)
    idx, w = s.sample()
    assert idx.shape == (100,) and w.shape == (100,)
    assert np.all(np.diff(idx) > 0)          # sorted, distinct
    assert np.all(w == 1.0)                  # exact membership mask
    assert 0 <= idx.min() and idx.max() < 100_000


def test_cohort_sampler_importance_weights_debias():
    total, k = 5000, 500
    scores = np.ones(total)
    scores[:100] = 50.0                      # hot clients
    s = CohortSampler(total_clients=total, per_round=k, tier="importance",
                      scores=scores, seed=1)
    idx, w = s.sample()
    assert idx.size == k
    p = scores / scores.sum()
    np.testing.assert_allclose(w, 1.0 / (k * p[idx]), rtol=1e-6)
    # hot clients are much more likely to appear, and carry smaller weights
    hot = (idx < 100).mean()
    assert hot > 0.1
    assert w[idx < 100].mean() < w[idx >= 100].mean()


def test_cohort_sampler_arrival_tier():
    s = CohortSampler(total_clients=20_000, per_round=1, tier="arrival",
                      rate=0.05, seed=2)
    idx, w = s.sample()
    assert 0.03 * 20_000 < idx.size < 0.07 * 20_000
    assert np.all(w == pytest.approx(20.0))  # 1/rate Horvitz-Thompson


def test_cohort_sampler_shard_weights_match_dense():
    s = CohortSampler(total_clients=1000, per_round=64, seed=3)
    idx, w = s.sample()
    dense = s.dense(idx, w, (1, 1000)).reshape(-1)
    rows = list(s.iter_shards(idx, w, shard=64))
    assert len(rows) == -(-1000 // 64)
    got = np.concatenate(rows)[:1000]
    np.testing.assert_array_equal(got, dense)
    # spot-check the binary-search slicing
    np.testing.assert_array_equal(s.shard_weights(idx, w, 3, 64),
                                  dense[3 * 64:4 * 64])


def test_cohort_sampler_device_partitions_match_shard_sequence():
    """device_partitions hands device d the same contiguous slice of the
    global shard sequence the engine's shard_map partition scans there —
    concatenated over devices it is the full (device-padded) sequence."""
    s = CohortSampler(total_clients=1000, per_round=64, seed=4)
    idx, w = s.sample()
    shard, devices = 64, 4
    n_shards = -(-1000 // shard)                       # 16
    padded = -(-n_shards // devices) * devices         # 16
    blocks = list(s.device_partitions(idx, w, shard=shard, devices=devices))
    assert len(blocks) == devices
    assert all(b.shape == (padded // devices, shard) for b in blocks)
    rows = list(s.iter_shards(idx, w, shard=shard))
    rows += [np.zeros(shard, np.float32)] * (padded - len(rows))
    np.testing.assert_array_equal(np.concatenate(blocks), np.stack(rows))
    # uneven: 5 shards over 2 devices pads to 6 (the trailing all-padding
    # shard densifies to a zero row)
    s2 = CohortSampler(total_clients=300, per_round=32, seed=5)
    i2, w2 = s2.sample()
    blocks = list(s2.device_partitions(i2, w2, shard=64, devices=2))
    assert [b.shape for b in blocks] == [(3, 64), (3, 64)]
    np.testing.assert_array_equal(blocks[1][-1], np.zeros(64, np.float32))
    with pytest.raises(ValueError):
        list(s2.device_partitions(i2, w2, shard=64, devices=0))


def test_cohort_sampler_validation():
    with pytest.raises(ValueError):
        CohortSampler(total_clients=10, per_round=11)
    with pytest.raises(ValueError):
        CohortSampler(total_clients=10, per_round=2, tier="nope")
    with pytest.raises(ValueError):
        CohortSampler(total_clients=10, per_round=2, tier="importance")
    with pytest.raises(ValueError):
        CohortSampler(total_clients=10, per_round=2, tier="arrival", rate=0.0)


def test_cohort_sampler_drives_streaming_round():
    """End-to-end: a CohortSampler mask through a streamed round matches the
    same mask through the vmap plan (uniform tier -> exact 0/1 mask)."""
    n = 24
    s = CohortSampler(total_clients=n, per_round=8, seed=9)
    mask = jnp.asarray(s.mask((1, n)))
    assert float(mask.sum()) == 8.0
    ref, _ = _run_rounds("zsign_packed(z=1,sigma=0.7)", "vmap", n=n,
                         mask=mask, rounds=2)
    got, _ = _run_rounds("zsign_packed(z=1,sigma=0.7)", "stream(shard=5)",
                         n=n, mask=mask, rounds=2)
    np.testing.assert_array_equal(np.asarray(ref.params["x"]),
                                  np.asarray(got.params["x"]))
