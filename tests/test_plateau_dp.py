"""Plateau criterion (§4.4) + DP accounting (Appendix F)."""
import math

import pytest

from repro.core.dp import calibrate_noise, compute_epsilon
from repro.core.plateau import PlateauController


def test_plateau_grows_on_stall():
    c = PlateauController(sigma_init=0.01, sigma_bound=0.5, kappa=3, beta=2.0)
    # improving: sigma stays
    for loss in [10, 9, 8, 7]:
        assert c.update(loss) == 0.01
    # stalled for kappa rounds: sigma doubles
    c.update(7.0), c.update(7.0)
    assert c.update(7.0) == 0.02
    # keeps doubling on repeated stalls, capped at bound
    for _ in range(40):
        c.update(7.0)
    assert c.sigma == 0.5


def test_plateau_resets_on_improvement():
    c = PlateauController(sigma_init=0.1, sigma_bound=1.0, kappa=2, beta=1.5)
    c.update(5.0)
    c.update(5.0)          # stale 1
    c.update(4.0)          # improvement resets
    c.update(4.0)          # stale 1
    assert c.sigma == 0.1


def test_plateau_validates_args():
    with pytest.raises(ValueError):
        PlateauController(sigma_init=1.0, sigma_bound=0.5, kappa=2)


def test_rdp_epsilon_monotone_in_noise():
    e1 = compute_epsilon(q=0.05, noise_multiplier=1.0, steps=500, delta=1e-3)
    e2 = compute_epsilon(q=0.05, noise_multiplier=2.0, steps=500, delta=1e-3)
    assert e2 < e1


def test_rdp_epsilon_monotone_in_steps():
    e1 = compute_epsilon(q=0.05, noise_multiplier=1.0, steps=100, delta=1e-3)
    e2 = compute_epsilon(q=0.05, noise_multiplier=1.0, steps=1000, delta=1e-3)
    assert e2 > e1


def test_calibrate_noise_hits_target():
    target = 4.0
    sig = calibrate_noise(q=0.028, steps=500, target_eps=target, delta=1e-3)
    eps = compute_epsilon(q=0.028, noise_multiplier=sig, steps=500, delta=1e-3)
    assert eps <= target * 1.01
    # and is tight: slightly less noise would violate
    eps_lo = compute_epsilon(q=0.028, noise_multiplier=sig * 0.9, steps=500,
                             delta=1e-3)
    assert eps_lo > target * 0.99


def test_full_participation_gaussian_rdp():
    # q=1: eps_alpha = alpha/(2 sigma^2); known closed form sanity
    e = compute_epsilon(q=1.0, noise_multiplier=5.0, steps=1, delta=1e-5)
    assert 0 < e < 2.0
