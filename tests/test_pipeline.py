"""Composable compression-pipeline API: spec grammar, legacy-factory
equivalence, stage composition, RoundContext policy, and the
previously-impossible compositions (dp over the packed 1-bit wire, EF over
top-k).

Contract under test (see core/compression.py):
  * the legacy monolithic class names are factory functions building the
    EQUIVALENT pipeline — bit-exact against the explicit ``Pipeline`` spec
    on encode, compressed-domain aggregate, and decode, including dead-
    client residual semantics (the ``make_compressor`` string entry point
    finished its deprecation cycle and was REMOVED in PR 7);
  * ``ef`` composes over any codec via the one residual rule
    ``codec_input - local_decode(payload)``;
  * a ``dp`` transform's noise FUSES into a downstream sign codec's sigma,
    so DP ships 1 bit/coord with no dense noise surface (jaxpr-enforced);
  * ``RoundContext`` is the one policy object: legacy kwargs and an explicit
    context build bit-identical round steps.
"""
import subprocess
import sys
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypo_compat import given, settings, st
from test_encode_fused import _max_f32_outvar_bytes, _walk_eqns

from repro.core import compression as C
from repro.core import fedavg, wire
from repro.core import noise as Z
from repro.core.context import RoundContext, resolve_backend


# ---------------------------------------------------------------------------
# spec grammar
# ---------------------------------------------------------------------------

def test_spec_parses_stages_and_values():
    p = C.Pipeline("dp(clip=1.5,noise=0.25)|zsign(encode_chunk_tiles=2)")
    assert isinstance(p.transforms[0], C.DPTransform)
    assert p.transforms[0].clip == 1.5
    assert isinstance(p.codec, C.SignCodec)
    assert p.codec.z == 1 and p.codec.encode_chunk_tiles == 2
    assert p.codec.sigma == 0.25          # dp noise fused into the codec
    assert p.name == "dp(clip=1.5,noise=0.25)|zsign(encode_chunk_tiles=2)"
    assert C.Pipeline("zsign(z=inf,sigma=2.0)").codec.z == Z.Z_INF


def test_spec_errors():
    for bad, match in [
            ("", "empty pipeline"),
            ("nope", "unknown codec stage"),
            ("zsign|ef", "unknown transform stage"),   # codec must come last
            ("ef|ef|zsign", "at most one ef"),
            ("zsign(sigma)", "must be key=value"),
            ("zsign(sigma=0.5", "malformed stage"),
            ("zsign(sigma_mode=nope)", "sigma_mode"),
    ]:
        with pytest.raises(ValueError, match=match):
            C.Pipeline(bad)
    with pytest.raises(ValueError, match="ambiguous noise"):
        C.Pipeline("dp(noise=0.5)|zsign(sigma=0.5)")
    with pytest.raises(ValueError, match="clip > 0"):
        C.Pipeline("dp(eps=2.0)|zsign")


def test_state_slots_are_keyed_and_collisions_fail_loudly():
    """The multi-slot state protocol: client state is a keyed dict over the
    stateful stages' declared slots; two stages claiming the same slot name
    is a BUILD-time error, not a silent shared buffer."""
    p = C.Pipeline("ef|zsign")
    st = p.init_state(16)
    assert set(st) == {"ef"} and st["ef"].shape == (16,)
    assert [s.name for s in p.state_slots(16)] == ["ef"]
    assert C.Pipeline("zsign(sigma=0.5)").init_state(16) is None

    class DupState:
        spec_name = "dup"
        stateful = True
        randomized = False

        def state_spec(self, n_coords):
            return (C.StateSlot("ef", (n_coords,)),)

    with pytest.raises(ValueError, match="collision"):
        C.Pipeline((C.ErrorFeedback(), DupState()), C.SignCodec())


def test_spec_roundtrips_through_canonical_string():
    for spec in ["ef|zsign", "dp(clip=1.0,noise=0.5)|zsign_packed",
                 "ef|topk(frac=0.05)", "qsgd(s=4)", "stosign", "identity"]:
        p = C.Pipeline(spec)
        q = C.Pipeline(p.spec)
        assert (q.transforms, q.codec) == (p.transforms, p.codec), spec


def test_ef_sign_scale_convenience_default():
    """ef in front of the NOISE-FREE sign codec defaults the wire to the
    EF-SignSGD mean-abs scale; an explicit scale wins, and noisy z-sign /
    sto-sign keep their own decode laws (no silent hybrid)."""
    assert C.Pipeline("ef|zsign").codec.scale == "mean_abs"
    assert C.Pipeline("ef|zsign(scale=none)").codec.scale == "none"
    assert C.Pipeline("zsign").codec.scale == "none"
    noisy = C.Pipeline("ef|zsign(z=1,sigma=0.5)")
    assert noisy.codec.scale == "none"
    # the Lemma-1 debias survives EF composition over noisy z-sign
    assert float(noisy.decode_mean(jnp.ones(()))) == pytest.approx(
        Z.eta_z(1) * 0.5)
    assert C.Pipeline("ef|stosign").codec.scale == "none"


def test_ef_wire_ignores_dynamic_sigma_like_legacy():
    """The noise-free EF-SignSGD wire ignores the engine's dynamic (Plateau)
    sigma, exactly as the legacy EFSignCompressor did (del sigma): payload
    bits stay noise-free and bit-identical with or without the override."""
    d = 64
    p = C.Pipeline("ef|zsign")
    flat = jnp.asarray(np.random.RandomState(0).randn(d), jnp.float32)
    key = jax.random.PRNGKey(0)
    e0, s0 = p.encode(key, flat, p.init_state(d))
    e1, s1 = p.encode(key, flat, p.init_state(d), sigma=jnp.float32(0.7))
    np.testing.assert_array_equal(np.asarray(e0["packed"]),
                                  np.asarray(e1["packed"]))
    np.testing.assert_array_equal(np.asarray(s0["ef"]), np.asarray(s1["ef"]))


def test_dp_fusion_requires_gaussian_sign_codec():
    """The dp accountant assumes the Gaussian mechanism: fusing into a
    z != 1 (e.g. bounded-uniform z=inf) or norm-mode sign codec would void
    the calibrated (eps, delta) guarantee and must refuse."""
    for bad in ["dp(clip=1.0,noise=0.5)|zsign(z=inf)",
                "dp(clip=1.0,noise=0.5)|stosign"]:
        with pytest.raises(ValueError, match="Gaussian"):
            C.Pipeline(bad)


def test_dynamic_sigma_refused_over_calibrated_dp_stage():
    """The Plateau override may not replace (eps, delta)-CALIBRATED dp noise
    — neither on the fused 1-bit pipeline nor on dp|dense. A hand-set
    dp(noise=..) carries no privacy promise and keeps the legacy dpgauss
    law: the dynamic sigma overrides it."""
    for spec in ["dp(clip=1.0,eps=2.0,steps=100)|zsign",
                 "dp(clip=1.0,eps=2.0,steps=100)|dense"]:
        p = C.Pipeline(spec)
        assert p.transforms[0].calibrated
        with pytest.raises(ValueError, match="Plateau"):
            p.with_context(RoundContext(dynamic_sigma=True))
        # and through the engine entry point
        with pytest.raises(ValueError, match="Plateau"):
            fedavg.build_round_step(lambda pr, b: 0.0, p,
                                    fedavg.FedConfig(), dynamic_sigma=True)
    # legacy dpgauss + Plateau still builds and consumes the dynamic sigma
    legacy = C.DPGaussianCompressor(sigma=0.3)
    step = fedavg.build_round_step(
        lambda pr, b: 0.5 * jnp.sum((pr["x"] - b["y"]) ** 2), legacy,
        fedavg.FedConfig(n_clients=2, client_lr=0.01), dynamic_sigma=True)
    st = fedavg.init_server_state({"x": jnp.zeros(8)}, fedavg.FedConfig(
        n_clients=2, client_lr=0.01), legacy, jax.random.PRNGKey(0),
        sigma0=0.7)
    st2, _ = jax.jit(step)(st, {"y": jnp.ones((1, 2, 1, 8))},
                           jnp.ones((1, 2)))
    assert np.all(np.isfinite(np.asarray(st2.params["x"])))


def test_dp_eps_and_noise_together_raise():
    with pytest.raises(ValueError, match="not.*both|one target"):
        C.Pipeline("dp(clip=1.0,eps=2.0,noise=0.3)|zsign")


def test_clip_only_dp_never_consumes_dynamic_sigma():
    """dp(clip=...) with NO noise over a dense codec: a dynamic sigma passed
    directly to encode must not inject noise into a noise-free pipeline."""
    p = C.Pipeline("dp(clip=1.0)|dense")
    flat = 10.0 * jnp.ones((32,))
    got, _ = p.encode(jax.random.PRNGKey(0), flat, None,
                      sigma=jnp.float32(0.5))
    from repro.core.dp import clip_flat
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(clip_flat(flat, 1.0)))


def test_fractional_z_rejected():
    with pytest.raises(ValueError, match="integer or 'inf'"):
        C.Pipeline("zsign(z=2.5)")


def test_ctx_plus_legacy_kwargs_conflict_raises():
    comp = C.Pipeline("zsign(sigma=0.5)")
    with pytest.raises(ValueError, match="not both"):
        fedavg.build_round_step(lambda p, b: 0.0, comp, fedavg.FedConfig(),
                                RoundContext(), agg_backend="jnp")
    import benchmarks  # noqa: F401 -- ensure package importable
    from benchmarks.common import run_fed
    with pytest.raises(ValueError, match="not both"):
        run_fed(lambda p, b: 0.0, {"x": jnp.zeros(4)}, lambda t: {},
                comp, fedavg.FedConfig(), rounds=1, ctx=RoundContext(),
                agg_backend="jnp")


def test_legacy_factories_reject_unknown_kwargs():
    """A typo'd hyper-parameter must fail loudly, as the old dataclass
    constructors did — never run the experiment with silent defaults."""
    with pytest.raises(TypeError):
        C.QSGDCompressor(sigma=0.5)
    with pytest.raises(TypeError):
        C.TopKCompressor(sigma=0.5)
    with pytest.raises(TypeError):
        C.DPGaussianCompressor(frac=0.1)
    with pytest.raises(TypeError):
        C.ZSignCompressor(frac=0.5)   # SignCodec has no such field


def test_spec_sigma_is_explicit_vanilla_sign_by_default():
    """The sigma=None optionality wart is gone: sigma is a plain float field,
    0.0 by default (= vanilla SignSGD, PRNG statically gated off)."""
    p = C.Pipeline("zsign")
    assert p.codec.sigma == 0.0
    flat = jnp.asarray([-2.0, -0.1, 0.0, 0.1, 3.0], jnp.float32)
    enc, _ = p.encode(jax.random.PRNGKey(0), flat, None)
    np.testing.assert_array_equal(
        np.asarray(wire.unpack_signs(enc))[:5],
        np.array([-1, -1, 1, 1, 1], np.int8))


def test_packed_sigma_zero_noprng_jaxpr_pinned():
    """Regression pin (satellite): the packed sign codec at sigma == 0 keeps
    its no-PRNG jaxpr guarantee under the new API, on every backend."""
    flat = jnp.ones((8192,))
    for backend in ["reference", "jnp", "pallas"]:
        p = C.Pipeline(f"zsign_packed(encode_backend={backend})")
        assert p.codec.sigma == 0.0
        jaxpr = jax.make_jaxpr(lambda k, f: p.encode(k, f, None)[0])(
            jax.random.PRNGKey(0), flat)
        for eqn in _walk_eqns(jaxpr.jaxpr):
            assert "threefry" not in eqn.primitive.name, (backend, eqn)
            assert "erf" not in eqn.primitive.name, (backend, eqn)


# ---------------------------------------------------------------------------
# legacy-factory equivalence: factory class name == explicit Pipeline spec,
# bit-exact (the make_compressor string shim is gone — see its removal test)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("factory,kw,spec", [
    (C.ZSignCompressor, {"z": 1, "sigma": 0.5}, "zsign(z=1,sigma=0.5)"),
    (C.ZSignCompressor, {"z": 0, "sigma": 2.0}, "zsign(z=inf,sigma=2.0)"),
    (C.PackedZSignCompressor, {"z": 1, "sigma": 0.5},
     "zsign_packed(sigma=0.5)"),
    (C.StoSignCompressor, {}, "stosign"),
    (C.EFSignCompressor, {}, "ef|zsign"),
    (C.QSGDCompressor, {"s": 2}, "qsgd(s=2)"),
    (C.TopKCompressor, {"frac": 0.25}, "ef|topk(frac=0.25)"),
    (C.DPGaussianCompressor, {"sigma": 0.3}, "dp(noise=0.3)|dense"),
], ids=lambda v: v if isinstance(v, str) else "")
def test_factory_encode_aggregate_decode_bit_exact(factory, kw, spec):
    """Legacy factory name vs the explicit spec string: identical payload
    bytes/values, identical masked aggregate, identical decode."""
    d, n = 1000, 4
    legacy = factory(**kw)
    pipe = C.Pipeline(spec)
    flat = jnp.asarray(np.random.RandomState(0).randn(d), jnp.float32)
    mask = jnp.asarray([1.0, 0.0, 1.0, 1.0])
    encs, states = {}, {}
    for label, comp in [("legacy", legacy), ("spec", pipe)]:
        st0 = comp.init_state(d)
        es, ss = [], []
        for i in range(n):
            e, s = comp.encode(jax.random.fold_in(jax.random.PRNGKey(7), i),
                               flat * (i + 1), st0)
            es.append(e)
            ss.append(s)
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *es)
        agg = comp.aggregate(stacked, mask, d)
        dec = comp.decode_mean(agg / jnp.sum(mask))
        encs[label] = (es, agg, dec)
        states[label] = ss
    for a, b in zip(jax.tree_util.tree_leaves(encs["legacy"]),
                    jax.tree_util.tree_leaves(encs["spec"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree_util.tree_leaves(states["legacy"]),
                    jax.tree_util.tree_leaves(states["spec"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("groups", [1, 2])
def test_efsign_factory_vs_ef_zsign_engine_bit_identical(groups):
    """EFSignCompressor() vs Pipeline("ef|zsign") through the ROUND
    ENGINE under partial participation: bit-identical params AND residuals
    every round (dead clients keep their residual bit-exactly on both)."""
    d, n = 48, 4
    y = jax.random.normal(jax.random.PRNGKey(2), (groups, n, 1, d))
    loss_fn = lambda p, b: 0.5 * jnp.sum((p["x"] - b["y"]) ** 2)
    cfg = fedavg.FedConfig(n_clients=n, client_groups=groups,
                           client_lr=0.01, server_lr=0.5)
    mask = jnp.ones((groups, n)).at[0, 1].set(0.0).at[groups - 1, 3].set(0.0)
    outs = {}
    for label, comp in [("legacy", C.EFSignCompressor()),
                        ("spec", C.Pipeline("ef|zsign"))]:
        step = jax.jit(fedavg.build_round_step(loss_fn, comp, cfg))
        st = fedavg.init_server_state({"x": jnp.zeros(d)}, cfg, comp,
                                      jax.random.PRNGKey(1))
        for _ in range(6):
            st, _ = step(st, {"y": y}, mask)
        outs[label] = (np.asarray(st.params["x"]),
                       np.asarray(st.comp_state["ef"]))
    np.testing.assert_array_equal(outs["legacy"][0], outs["spec"][0])
    np.testing.assert_array_equal(outs["legacy"][1], outs["spec"][1])
    # dead clients' residuals froze after round 1 only if masked — sanity:
    assert outs["legacy"][1].shape == (groups, n, d)


# ---------------------------------------------------------------------------
# ef over top-k: residual correctness
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=8, max_value=400),
       st.integers(min_value=1, max_value=97))
def test_ef_topk_residual_conservation_property(d, seed):
    """EF invariant over the COO codec, any shape: transmitted + residual
    == codec input EXACTLY (p[idx] - p[idx] == 0 in f32), and the residual
    is zero exactly on the selected coordinates."""
    rng = np.random.RandomState(seed)
    p = C.Pipeline("ef|topk(frac=0.2)")
    state = {"ef": jnp.asarray(rng.randn(d), jnp.float32) * 0.1}
    flat = jnp.asarray(rng.randn(d), jnp.float32)
    enc, res = p.encode(None, flat, state)
    dense = np.zeros(d, np.float32)
    dense[np.asarray(enc["indices"])] = np.asarray(enc["values"])
    np.testing.assert_array_equal(dense + np.asarray(res["ef"]),
                                  np.asarray(flat + state["ef"]))
    assert np.all(np.asarray(res["ef"])[np.asarray(enc["indices"])] == 0.0)


def test_ef_topk_error_feedback_contracts():
    """EF over top-k compensates: the running decoded average of a constant
    gradient converges to the gradient even at frac=0.25."""
    p = C.Pipeline("ef|topk(frac=0.25)")
    flat = jnp.asarray([1.0, -0.2, 0.05, 3.0])
    state = p.init_state(4)
    dec_sum = np.zeros(4)
    T = 200
    for i in range(T):
        enc, state = p.encode(None, flat, state)
        dec_sum += np.asarray(
            p.aggregate(jax.tree.map(lambda x: x[None], enc),
                        jnp.ones((1,)), 4))
    np.testing.assert_allclose(dec_sum / T, np.asarray(flat), atol=0.05)


def test_ef_composes_over_qsgd():
    """EF over the quantizer: residual == p - quantized, by the one rule."""
    d = 64
    p = C.Pipeline("ef|qsgd(s=1)")
    flat = jnp.asarray(np.random.RandomState(0).randn(d), jnp.float32)
    enc, res = p.encode(jax.random.PRNGKey(3), flat, p.init_state(d))
    np.testing.assert_allclose(np.asarray(enc) + np.asarray(res["ef"]),
                               np.asarray(flat), atol=1e-6)


# ---------------------------------------------------------------------------
# dp composition: fusion into the sign codec, 1-bit wire, no dense surface
# ---------------------------------------------------------------------------

def test_dp_noise_fuses_into_sign_codec_bit_exact():
    """dp(clip,noise)|zsign == clip, then the SAME fused stochastic-sign
    encode a bare zsign(sigma=noise) codec runs — bit-identical wire bytes
    for the same key."""
    d, clipn, sig = 3 * 8192 + 17, 1.0, 0.5
    key = jax.random.PRNGKey(11)
    flat = 3.0 * jax.random.normal(jax.random.PRNGKey(1), (d,))
    fused = C.Pipeline(f"dp(clip={clipn},noise={sig})|zsign")
    assert fused.codec.sigma == sig and fused.transforms[0].noise == 0.0
    got, _ = fused.encode(key, flat, None)
    from repro.core.dp import clip_flat
    want, _ = C.Pipeline(f"zsign(sigma={sig})").encode(
        key, clip_flat(flat, clipn), None)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_dp_eps_calibration_monotone():
    tight = C.Pipeline("dp(clip=1.0,eps=1.0,steps=100)|zsign")
    loose = C.Pipeline("dp(clip=1.0,eps=8.0,steps=100)|zsign")
    assert tight.codec.sigma > loose.codec.sigma > 0.0
    assert tight.wire_bits_per_coord == 1.0


def test_dp_over_dense_is_legacy_dpgauss_plus_clip():
    """Over a dense codec the dp noise stays in the transform (32-bit DP-
    FedAvg); clip applies before the draw."""
    d = 256
    key = jax.random.PRNGKey(5)
    flat = 10.0 * jnp.ones((d,))
    p = C.Pipeline("dp(clip=1.0,noise=0.3)|dense")
    assert p.transforms[0].noise == 0.3       # NOT fused
    got, _ = p.encode(key, flat, None)
    from repro.core.dp import clip_flat
    want = clip_flat(flat, 1.0) + 0.3 * jax.random.normal(key, (d,))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_dp_packed_composition_trains_and_has_no_dense_noise_surface():
    """The previously-impossible dp|zsign_packed: trains end-to-end on the
    consensus problem at 1 bit/coord, and the vmapped client fan-out jaxpr
    contains NO jax.random draw (threefry2x32 primitive) and no fp32
    intermediate beyond the 1x (n_clients, d) transform stream — the dense
    noise surface is NOT reintroduced."""
    pipe = C.Pipeline("dp(clip=2.0,noise=1.0)|zsign_packed")
    assert pipe.wire_bits_per_coord == 1.0
    # jaxpr enforcement on the client fan-out
    n, d = 16, 2 * 8192 + 100
    keys = jax.random.split(jax.random.PRNGKey(0), n)
    flats = jnp.zeros((n, d))
    jaxpr = jax.make_jaxpr(
        jax.vmap(lambda k, f: pipe.encode(k, f, None)[0]))(keys, flats)
    for eqn in _walk_eqns(jaxpr.jaxpr):
        assert eqn.primitive.name != "threefry2x32", eqn
    worst = _max_f32_outvar_bytes(jaxpr.jaxpr)
    assert worst <= 4 * n * d, worst      # <= one clipped-gradient surface
    # end-to-end: converges toward the (noisy) consensus optimum
    dcons, ncl = 50, 8
    y = jax.random.normal(jax.random.PRNGKey(3), (1, ncl, 1, dcons))
    loss_fn = lambda p, b: 0.5 * jnp.sum((p["x"] - b["y"]) ** 2)
    cfg = fedavg.FedConfig(n_clients=ncl, client_lr=0.01, server_lr=1.0)
    step = jax.jit(fedavg.build_round_step(loss_fn, pipe, cfg))
    st = fedavg.init_server_state({"x": jnp.zeros(dcons)}, cfg, pipe,
                                  jax.random.PRNGKey(1))
    d0 = float(jnp.linalg.norm(st.params["x"] - y[0, :, 0].mean(0)))
    for _ in range(400):
        st, m = step(st, {"y": y}, jnp.ones((1, ncl)))
    d1 = float(jnp.linalg.norm(st.params["x"] - y[0, :, 0].mean(0)))
    assert d1 < 0.5 * d0
    assert float(m.uplink_bits) == ncl * dcons  # 1 bit/coord on the wire


def test_ef_topk_no_dense_aggregate_surface():
    """EF over top-k: the server aggregation jaxpr scatter-adds COO payloads
    — no (n_clients, d) fp32 dense per-client surface appears."""
    n, d, k = 16, 100_000, 1000
    pipe = C.Pipeline("ef|topk(frac=0.01)")
    payload = {"values": jnp.zeros((n, k)),
               "indices": jnp.zeros((n, k), jnp.int32)}
    jaxpr = jax.make_jaxpr(
        lambda p, m: pipe.aggregate(p, m, d))(payload, jnp.ones((n,)))
    for eqn in _walk_eqns(jaxpr.jaxpr):
        for var in eqn.outvars:
            aval = getattr(var, "aval", None)
            if aval is None or not hasattr(aval, "shape"):
                continue
            assert int(np.prod(aval.shape, dtype=np.int64)) < n * d, eqn


@pytest.mark.parametrize("spec", ["dp(clip=1.0,noise=0.1)|zsign_packed",
                                  "ef|topk(frac=0.05)"])
def test_cli_trains_pipeline_spec_end_to_end(spec):
    """The train CLI accepts --pipeline spec strings for the previously-
    impossible compositions and completes rounds."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "qwen2_0_5b",
         "--reduced", "--rounds", "1", "--clients", "2", "--local-steps",
         "1", "--seq-len", "32", "--micro-batch", "1", "--pipeline", spec],
        capture_output=True, text=True, timeout=300,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
        cwd=__import__("os").path.dirname(__import__("os").path.dirname(
            __file__)))
    assert out.returncode == 0, out.stderr[-2000:]
    assert f"compressor={spec}" in out.stdout
    assert "# done: 1 rounds" in out.stdout


# ---------------------------------------------------------------------------
# RoundContext policy
# ---------------------------------------------------------------------------

def test_round_context_equals_legacy_kwargs_bit_identical():
    """build_round_step(ctx=RoundContext(...)) and the legacy kwargs spell
    the same round: bit-identical params after several rounds."""
    d, n = 64, 6
    comp = C.Pipeline("zsign(z=1,sigma=1.0)")
    loss_fn = lambda p, b: 0.5 * jnp.sum((p["x"] - b["y"]) ** 2)
    cfg = fedavg.FedConfig(n_clients=n, client_lr=0.01, server_lr=0.3)
    y = jax.random.normal(jax.random.PRNGKey(2), (1, n, 1, d))
    mask = jnp.ones((1, n)).at[0, 2].set(0.0)
    outs = {}
    for label, kw in [
            ("ctx", dict(ctx=RoundContext(agg_backend="jnp",
                                          encode_backend="jnp",
                                          weights_are_mask=True))),
            ("legacy", dict(agg_backend="jnp", encode_backend="jnp",
                            weights_are_mask=True))]:
        step = jax.jit(fedavg.build_round_step(loss_fn, comp, cfg, **kw))
        st = fedavg.init_server_state({"x": jnp.zeros(d)}, cfg, comp,
                                      jax.random.PRNGKey(1))
        for _ in range(4):
            st, _ = step(st, {"y": y}, mask)
        outs[label] = np.asarray(st.params["x"])
    np.testing.assert_array_equal(outs["ctx"], outs["legacy"])


def test_with_context_per_stage_rebinding():
    ctx = RoundContext(agg_backend="dense", encode_backend="reference",
                       weights_are_mask=True)
    # sign codec: both backends rebound, mask guarantee applied
    p = C.Pipeline("zsign(sigma=0.5)").with_context(ctx)
    assert p.codec.agg_backend == "dense"
    assert p.codec.encode_backend == "reference"
    assert p.codec.weights_are_mask
    # None backends keep the stage's own pin (zsign_packed stays pallas)
    q = C.Pipeline("zsign_packed").with_context(RoundContext())
    assert q.codec.encode_backend == "pallas"
    # scale-weighted (EF) aggregation never gets the 0/1-mask flag: its
    # weights are mask * scale, not a membership mask
    e = C.Pipeline("ef|zsign").with_context(ctx)
    assert not e.codec.weights_are_mask
    # non-sign codecs have no backend fields to rebind
    t = C.Pipeline("ef|topk")
    assert t.with_context(ctx) is t


def test_round_context_and_backend_validation():
    with pytest.raises(ValueError, match="unknown agg backend"):
        RoundContext(agg_backend="nope")
    with pytest.raises(ValueError, match="unknown encode backend"):
        RoundContext(encode_backend="dense")
    with pytest.raises(ValueError, match="unknown agg backend"):
        resolve_backend("agg", "reference")
    assert resolve_backend("agg", "auto") in ("jnp", "pallas")
    assert resolve_backend("encode", "reference") == "reference"


# ---------------------------------------------------------------------------
# deprecation shim: removed in PR 7
# ---------------------------------------------------------------------------

def test_make_compressor_shim_is_gone_and_api_warning_free():
    """The make_compressor(name) string entry point finished its deprecation
    cycle: the attribute no longer exists (no half-removed stub), and the
    surviving API — Pipeline specs and the legacy factory names — emits no
    DeprecationWarning."""
    assert not hasattr(C, "make_compressor")
    assert "make_compressor" not in C.__all__
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        C.Pipeline("ef|zsign")
        C.ZSignCompressor(sigma=0.5)
        C.EFSignCompressor()
    assert not [x for x in w if issubclass(x.category, DeprecationWarning)]


def test_pipeline_is_hashable_and_fields_clean():
    """Frozen dataclass: usable as a static jit closure; the engine-visible
    fields are exactly (transforms, codec, name) — per-stage knobs like
    weights_are_mask live on stages, not the pipeline."""
    import dataclasses
    p = C.Pipeline("ef|zsign")
    assert hash(p) == hash(C.Pipeline("ef|zsign"))
    assert {f.name for f in dataclasses.fields(p)} == \
        {"transforms", "codec", "name"}
