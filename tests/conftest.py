import os

# Tests run on the single host CPU device (the 512-device override is ONLY
# for launch/dryrun.py). Keep XLA quiet and single-threaded-friendly.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
