"""Per-architecture smoke tests: REDUCED same-family configs, one forward /
train step on CPU, asserting output shapes + finiteness (the FULL configs are
exercised via the dry-run only — ShapeDtypeStructs, no allocation)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.common import get_arch, list_archs
from repro.core import compression, fedavg
from repro.models.api import build_model


def make_batch(spec, vocab, key):
    return jax.tree.map(
        lambda s: (jax.random.randint(key, s.shape, 0, vocab)
                   if s.dtype == jnp.int32
                   else jax.random.normal(key, s.shape, s.dtype)), spec)


@pytest.mark.parametrize("arch_id", list_archs())
def test_reduced_config_train_step(arch_id):
    arch = get_arch(arch_id).reduced()
    bundle = build_model(arch.model)
    key = jax.random.PRNGKey(0)
    params = bundle.init(key)
    spec = bundle.train_batch_spec(2, 32)
    batch = make_batch(spec, arch.model.vocab, key)
    loss, grads = jax.jit(jax.value_and_grad(bundle.loss_fn))(params, batch)
    assert jnp.isfinite(loss), f"{arch_id}: non-finite loss"
    gn = sum(jnp.sum(jnp.square(g)) for g in jax.tree_util.tree_leaves(grads))
    assert jnp.isfinite(gn), f"{arch_id}: non-finite grads"
    assert float(loss) > 0.0


@pytest.mark.parametrize("arch_id", list_archs())
def test_reduced_config_decode_step(arch_id):
    arch = get_arch(arch_id).reduced()
    bundle = build_model(arch.model)
    key = jax.random.PRNGKey(1)
    params = bundle.init(key)
    cache = bundle.init_cache(2, 64)
    tokens = jnp.zeros((2, 1), jnp.int32)
    logits, cache2 = jax.jit(bundle.decode_step)(params, cache, tokens,
                                                 jnp.int32(5))
    assert logits.shape == (2, 1, arch.model.vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch_id}: non-finite logits"
    # cache structure preserved
    assert (jax.tree_util.tree_structure(cache2)
            == jax.tree_util.tree_structure(cache))


@pytest.mark.parametrize("arch_id", ["qwen2_0_5b", "granite_moe_1b_a400m",
                                     "xlstm_350m"])
def test_reduced_fed_round(arch_id):
    """Full federated round on a reduced model: 4 clients, E=2, z-sign."""
    arch = get_arch(arch_id).reduced()
    bundle = build_model(arch.model)
    comp = compression.Pipeline("zsign(z=1,sigma=0.05)")
    cfg = fedavg.FedConfig(n_clients=4, local_steps=2, client_lr=0.05,
                           server_lr=0.5)
    step = jax.jit(fedavg.build_round_step(bundle.loss_fn, comp, cfg))
    params = bundle.init(jax.random.PRNGKey(0))
    state = fedavg.init_server_state(params, cfg, comp, jax.random.PRNGKey(1))
    spec = fedavg.make_batch_spec(cfg, bundle.train_batch_spec(2, 32))
    batch = make_batch(spec, arch.model.vocab, jax.random.PRNGKey(2))
    mask = jnp.ones((1, 4))
    l0 = None
    for i in range(5):
        state, metrics = step(state, batch, mask)
        assert jnp.isfinite(metrics.loss)
        if l0 is None:
            l0 = float(metrics.loss)
    # same batch each round: loss must drop (memorization)
    assert float(metrics.loss) < l0


def test_decode_matches_forward_dense():
    """KV-cache decode == teacher-forced forward logits, position by position."""
    arch = get_arch("qwen2_0_5b").reduced()
    bundle = build_model(arch.model)
    from repro.models import transformer as T
    params = bundle.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              arch.model.vocab)
    full_logits, _ = T.forward(params, toks, arch.model)
    cache = bundle.init_cache(2, 8)
    outs = []
    for t in range(8):
        lg, cache = bundle.decode_step(params, cache, toks[:, t:t + 1],
                                       jnp.int32(t))
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    assert jnp.max(jnp.abs(dec_logits - full_logits)) < 2e-2
