"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracle
(interpret mode on CPU; identical code path runs compiled on TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypo_compat import given, settings, st

from repro.kernels.zsign import ops, ref


@pytest.mark.parametrize("size", [8, 64, 8192, 8192 * 2, 8192 * 3 + 17,
                                  100_003, 262_144])
@pytest.mark.parametrize("sigma", [0.0, 0.3, 5.0])
def test_compress_matches_oracle(size, sigma):
    k1, k2 = jax.random.split(jax.random.PRNGKey(size))
    x = jax.random.normal(k1, (size,))
    noise = jax.random.normal(k2, (size,))
    got = ops.zsign_compress(x, noise, sigma)
    pad = (-size) % ops.TILE
    want = ref.zsign_compress_ref(jnp.pad(x, (0, pad)), jnp.pad(noise, (0, pad)),
                                  sigma)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n_clients", [1, 2, 16])
@pytest.mark.parametrize("size", [8192, 24_576, 99_991])
def test_decompress_sum_matches_oracle(n_clients, size):
    keys = jax.random.split(jax.random.PRNGKey(7), n_clients * 2)
    packed = []
    for i in range(n_clients):
        x = jax.random.normal(keys[2 * i], (size,))
        nz = jax.random.normal(keys[2 * i + 1], (size,))
        packed.append(ops.zsign_compress(x, nz, 1.0))
    packed = jnp.stack(packed)
    got = ops.zsign_decompress_sum(packed, size)
    want = ref.zsign_decompress_sum_ref(packed)[:size]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


def test_compress_decompress_end_to_end_sign_mean():
    """kernel pipeline == direct sign computation (the int8 psum path)."""
    n, size = 8, 16_384
    xs = jax.random.normal(jax.random.PRNGKey(0), (n, size))
    ns = jax.random.normal(jax.random.PRNGKey(1), (n, size))
    sigma = 0.7
    packed = jnp.stack([ops.zsign_compress(xs[i], ns[i], sigma)
                        for i in range(n)])
    mean_sign = ops.zsign_decompress_sum(packed, size) / n
    direct = jnp.mean(jnp.where(xs + sigma * ns >= 0, 1.0, -1.0), axis=0)
    np.testing.assert_allclose(np.asarray(mean_sign), np.asarray(direct))


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=1, max_value=70_000),
       st.floats(min_value=0.0, max_value=10.0, allow_nan=False))
def test_compress_property_any_shape(size, sigma):
    x = jnp.asarray(np.random.RandomState(size).randn(size), jnp.float32)
    noise = jnp.asarray(np.random.RandomState(size + 1).randn(size), jnp.float32)
    got = ops.zsign_compress(x, noise, sigma)
    # unpack and compare against elementwise signs
    from repro.core.compression import unpack_signs
    signs = unpack_signs(got)[:size]
    want = jnp.where(x + sigma * noise >= 0, 1, -1).astype(jnp.int8)
    np.testing.assert_array_equal(np.asarray(signs), np.asarray(want))


def test_wire_size_is_one_bit_per_coord():
    x = jnp.ones(8192)
    out = ops.zsign_compress(x, x, 0.0)
    assert out.size == 8192 // 8 and out.dtype == jnp.uint8


def test_packed_compressor_matches_int8_path():
    """PackedZSignCompressor (Pallas 1-bit wire) produces the same training
    trajectory as the dense int8 z-sign path (same rng stream)."""
    import numpy as np
    from repro.core import compression, fedavg
    d, n = 100, 4
    y = jax.random.normal(jax.random.PRNGKey(0), (1, n, d))
    loss_fn = lambda p, b: 0.5 * jnp.sum((p["x"] - b["y"]) ** 2)
    cfg = fedavg.FedConfig(n_clients=n, client_lr=0.01, server_lr=0.05)
    batch = {"y": y[:, :, None]}
    mask = jnp.ones((1, n))
    outs = {}
    for name in ["zsign", "zsign_packed"]:
        comp = compression.Pipeline(f"{name}(z=1,sigma=1.0)")
        step = jax.jit(fedavg.build_round_step(loss_fn, comp, cfg))
        st = fedavg.init_server_state({"x": jnp.zeros(d)}, cfg, comp,
                                      jax.random.PRNGKey(1))
        for _ in range(20):
            st, m = step(st, batch, mask)
        outs[name] = np.asarray(st.params["x"])
        assert float(m.uplink_bits) == n * d  # 1 bit per coordinate
    np.testing.assert_allclose(outs["zsign"], outs["zsign_packed"], atol=1e-8)


@pytest.mark.parametrize("size", [64, 8192, 50_000])
@pytest.mark.parametrize("scale", [0.1, 1.0])
def test_ef_kernel_matches_oracle(size, scale):
    from repro.kernels.efsign import ops as E
    from repro.kernels.efsign import ref as ER
    k1, k2 = jax.random.split(jax.random.PRNGKey(size))
    g = jax.random.normal(k1, (size,))
    e = jax.random.normal(k2, (size,)) * 0.3
    q, e_new = E.ef_sign_update(g, e, scale)
    q_ref, e_ref = ER.ef_sign_update_ref(g, e, scale)
    np.testing.assert_allclose(np.asarray(q), np.asarray(q_ref), atol=1e-6)
    np.testing.assert_allclose(np.asarray(e_new), np.asarray(e_ref), atol=1e-6)
    # EF invariant: q + e_new == g + e exactly (compression error conserved)
    np.testing.assert_allclose(np.asarray(q + e_new), np.asarray(g + e),
                               atol=1e-5)


def test_efsign_compressor_kernel_path_matches():
    """Pure-jnp and fused-Pallas EF paths produce identical wire payloads
    and residual buffers over repeated flat encodes."""
    from repro.core import compression
    import numpy as np
    flat = jnp.asarray(np.random.RandomState(0).randn(500), jnp.float32)
    c1 = compression.Pipeline("ef|zsign")
    c2 = compression.Pipeline("ef|zsign(use_kernel=true)")
    s1, s2 = c1.init_state(500), c2.init_state(500)
    for i in range(5):
        e1, s1 = c1.encode(None, flat, s1)
        e2, s2 = c2.encode(None, flat, s2)
    # kernel payload is tile-padded; shared byte prefix must be identical
    n_bytes = e1["packed"].size
    np.testing.assert_array_equal(np.asarray(e1["packed"]),
                                  np.asarray(e2["packed"])[:n_bytes])
    np.testing.assert_allclose(np.asarray(e1["scale"]),
                               np.asarray(e2["scale"]), atol=1e-6)
    np.testing.assert_allclose(np.asarray(s1["ef"]), np.asarray(s2["ef"]),
                               atol=1e-5)


def test_packed_wire_bytes_match_pure_jnp_pack():
    """Kernel bitpack and wire.pack_flat produce the same byte stream on the
    shared coordinate range (kernel pads to its 8192 tile)."""
    from repro.core import wire
    d = 10_003
    y = jax.random.normal(jax.random.PRNGKey(3), (d,))
    got = ops.zsign_compress(y, jnp.zeros((d,)), 0.0)
    want = wire.pack_flat(y)
    np.testing.assert_array_equal(np.asarray(got)[: want.size],
                                  np.asarray(want))
