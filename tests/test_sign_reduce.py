"""Fused weighted sign-reduce: kernel/oracle equivalence suite.

The server aggregation path has four implementations that must agree:

  wire.unpack_sum_dense   legacy dense-sign-matrix oracle (einsum)
  wire.unpack_sum         general weighted bit-sliced jnp path (CPU)
  wire.unpack_sum_mask    0/1-mask popcount fast path (CPU)
  kernels/zsign sign_reduce   fused Pallas kernel (TPU; interpret on CPU)

Exactness contract (see wire.py docstrings):
  * 0/1 masks: ALL paths are bit-exact vs the oracle — the sums are small
    integers, exactly representable in f32 under any association order.
  * arbitrary fp32 weights (EF per-client scales): the kernel and
    wire.unpack_sum share the same blocked client accumulation order, so
    they are bit-exact vs EACH OTHER; vs the dense oracle they agree to
    f32 rounding (different association order).
Covers weighted, masked (dead clients), EF per-client scales,
non-multiple-of-tile d, pack padding, and client counts off the kernel's
CLIENT_BLK boundary.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypo_compat import given, settings, st

from repro.core import compression as C
from repro.core import wire
from repro.kernels.zsign import ops, ref
from repro.kernels.zsign import zsign as ZK


def _payload(rng, n, n_bytes):
    return jnp.asarray(rng.randint(0, 256, (n, n_bytes)), jnp.uint8)


def test_client_blk_constants_match():
    """wire.py mirrors the kernel's accumulation blocking — keep in sync."""
    assert wire.SIGN_REDUCE_CLIENT_BLK == ZK.CLIENT_BLK


@pytest.mark.parametrize("n", [1, 2, 7, 8, 9, 16, 33])
@pytest.mark.parametrize("n_bytes", [1, 13, 1024, 4097])
def test_mask_all_paths_bit_exact(n, n_bytes):
    """0/1 masks (incl. dead clients): every path == dense oracle exactly."""
    rng = np.random.RandomState(n * 1000 + n_bytes)
    packed = _payload(rng, n, n_bytes)
    mask = jnp.asarray(rng.randint(0, 2, n).astype(np.float32))
    want = np.asarray(wire.unpack_sum_dense(packed, mask))
    np.testing.assert_array_equal(
        np.asarray(wire.unpack_sum(packed, mask)), want)
    np.testing.assert_array_equal(
        np.asarray(wire.unpack_sum_mask(packed, mask)), want)
    np.testing.assert_array_equal(
        np.asarray(ops.sign_reduce(packed, mask)), want)


@pytest.mark.parametrize("n", [1, 3, 8, 20, 64])
@pytest.mark.parametrize("n_bytes", [5, 1024, 12501])
def test_fp32_weights_kernel_matches_jnp_bit_exact(n, n_bytes):
    """Arbitrary per-client fp32 weights (the EF case): the Pallas kernel
    and wire.unpack_sum accumulate in the same blocked client order and must
    agree bit-for-bit; both agree with the dense oracle to f32 rounding."""
    rng = np.random.RandomState(n * 7919 + n_bytes)
    packed = _payload(rng, n, n_bytes)
    w = jnp.asarray(rng.randn(n).astype(np.float32))
    got_k = np.asarray(ops.sign_reduce(packed, w))
    got_j = np.asarray(wire.unpack_sum(packed, w))
    np.testing.assert_array_equal(got_k, got_j)
    want = np.asarray(wire.unpack_sum_dense(packed, w))
    np.testing.assert_allclose(got_k, want, rtol=1e-5,
                               atol=1e-6 * max(1, n))
    # the two dense-matrix oracle formulations are themselves identical
    np.testing.assert_array_equal(
        np.asarray(ref.sign_reduce_ref(packed, w)), want)


def test_kernel_zero_weight_rows_contribute_nothing():
    """Dead clients (weight 0) drop out exactly, matching a physically
    smaller stack — including when masking changes the padded client count."""
    rng = np.random.RandomState(0)
    packed = _payload(rng, 11, 2048)
    w = jnp.asarray(rng.rand(11).astype(np.float32))
    mask = jnp.asarray([1, 0, 1, 1, 0, 0, 1, 1, 1, 0, 1], jnp.float32)
    got = np.asarray(ops.sign_reduce(packed, w * mask))
    live = np.asarray(mask) > 0
    want = np.asarray(ops.sign_reduce(
        packed[np.where(live)[0]],
        jnp.asarray(np.asarray(w)[live])))
    # same blocked order only when live clients are a prefix — compare via
    # the jnp path which is bit-identical to the kernel per construction
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-5)
    np.testing.assert_array_equal(
        got, np.asarray(wire.unpack_sum(packed, w * mask)))


@pytest.mark.parametrize("d", [8, 64, 8192, 8192 * 2 + 136, 100_008])
def test_tile_and_pack_padding(d):
    """d off the 8192-element kernel tile: padded bytes/clients never leak
    into the leading d coordinates."""
    rng = np.random.RandomState(d)
    n = 5
    n_bytes = d // 8
    packed = _payload(rng, n, n_bytes)
    mask = jnp.ones((n,), jnp.float32)
    got = ops.sign_reduce(packed, mask)
    assert got.shape == (d,)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(wire.unpack_sum_dense(packed, mask)))


def test_efsign_scales_through_all_backends():
    """EF aggregation (weights = mask * per-client scale) is identical
    through jnp and pallas backends, and rounding-close to dense."""
    d, n = 3001, 6
    rng = np.random.RandomState(3)
    flats = [jnp.asarray(rng.randn(d), jnp.float32) * (i + 0.5)
             for i in range(n)]
    encs = []
    efsign = C.Pipeline("ef|zsign")
    for f in flats:
        e, _ = efsign.encode(None, f, efsign.init_state(d))
        encs.append(e)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *encs)
    mask = jnp.asarray([1, 1, 0, 1, 0, 1], jnp.float32)
    outs = {}
    for backend in ["jnp", "pallas", "dense"]:
        comp = C.Pipeline(f"ef|zsign(agg_backend={backend})")
        outs[backend] = np.asarray(comp.aggregate(stacked, mask, d))
    np.testing.assert_array_equal(outs["jnp"], outs["pallas"])
    np.testing.assert_allclose(outs["jnp"], outs["dense"], rtol=1e-5,
                               atol=1e-5)


def _with_opts(spec: str, opts: str) -> str:
    """Append codec kwargs to the last stage of a pipeline spec string."""
    if spec.endswith(")"):
        return f"{spec[:-1]},{opts})"
    return f"{spec}({opts})"


@pytest.mark.parametrize("spec", ["zsign(z=1,sigma=0.5)", "stosign",
                                  "zsign_packed(z=1,sigma=0.5)"])
def test_mask_compressors_identical_across_backends(spec):
    """zsign/stosign/zsign_packed aggregation is bit-identical through every
    backend (mask weights -> integer sums)."""
    d, n = 10_007, 9
    rng = np.random.RandomState(11)
    spec_flat = jnp.asarray(rng.randn(d), jnp.float32)
    key = jax.random.PRNGKey(0)
    encs = []
    base = C.Pipeline(spec)
    for i in range(n):
        e, _ = base.encode(jax.random.fold_in(key, i), spec_flat, None)
        encs.append(e)
    stacked = jnp.stack(encs)
    mask = jnp.asarray(rng.randint(0, 2, n).astype(np.float32))
    outs = []
    for backend in C.AGG_BACKENDS:
        comp = C.Pipeline(_with_opts(spec, f"agg_backend={backend}"))
        outs.append(np.asarray(comp.aggregate(stacked, mask, d)))
    for o in outs[1:]:
        np.testing.assert_array_equal(outs[0], o)


def test_fractional_weights_correct_on_every_backend():
    """Regression: data-size-proportional (non-0/1) client weights through
    ZSign/StoSign aggregate must be weighted correctly on every backend —
    the popcount membership specialization must never be auto-dispatched."""
    rng = np.random.RandomState(2)
    packed = _payload(rng, 4, 8)
    w = jnp.asarray([0.5, 0.5, 1.0, 0.0], jnp.float32)
    want = np.asarray(wire.unpack_sum_dense(packed, w))
    for name in ["zsign", "stosign"]:
        for backend in ["jnp", "pallas", "dense"]:
            comp = C.Pipeline(f"{name}(agg_backend={backend})")
            got = np.asarray(comp.aggregate(packed, w, 64))
            np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6,
                                       err_msg=f"{name}/{backend}")


def test_unknown_backend_raises():
    packed = jnp.zeros((2, 8), jnp.uint8)
    with pytest.raises(ValueError, match="unknown agg backend"):
        C.sign_reduce(packed, jnp.ones((2,)), "nope")


def _walk_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            inner = getattr(v, "jaxpr", None)
            if inner is not None:
                yield from _walk_eqns(inner)


def test_no_dense_sign_matrix_in_aggregate_jaxpr():
    """The (n_clients, d) fp32/int8 sign intermediate must not appear
    anywhere in the sign-family server aggregation path (including inside
    nested jits)."""
    n, n_bytes = 16, 8192
    d = n_bytes * 8
    for name, backend in [("zsign", "jnp"), ("stosign", "jnp"),
                          ("efsign", "jnp"), ("zsign", "pallas")]:
        spec = "ef|zsign" if name == "efsign" else name
        comp = C.Pipeline(_with_opts(spec, f"agg_backend={backend}"))
        if name == "efsign":
            payload = {"packed": jnp.zeros((n, n_bytes), jnp.uint8),
                       "scale": jnp.ones((n,))}
            fn = lambda p, m: comp.aggregate(p, m, d)
            jaxpr = jax.make_jaxpr(fn)(payload, jnp.ones((n,)))
        else:
            jaxpr = jax.make_jaxpr(
                lambda p, m: comp.aggregate(p, m, d))(
                    jnp.zeros((n, n_bytes), jnp.uint8), jnp.ones((n,)))
        for eqn in _walk_eqns(jaxpr.jaxpr):
            for var in list(eqn.outvars) + list(eqn.invars):
                aval = getattr(var, "aval", None)
                if aval is None or not hasattr(aval, "shape"):
                    continue
                if (tuple(aval.shape)[-2:] == (n, d)
                        and aval.dtype in (jnp.float32, jnp.int8)):
                    raise AssertionError(
                        f"{name}/{backend}: dense (n_clients, d) "
                        f"{aval.dtype} sign matrix in aggregation jaxpr: "
                        f"{eqn}")


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=1, max_value=40),
       st.integers(min_value=1, max_value=5000))
def test_property_mask_exact_any_shape(n, n_bytes):
    rng = np.random.RandomState(n * 31 + n_bytes)
    packed = _payload(rng, n, n_bytes)
    mask = jnp.asarray(rng.randint(0, 2, n).astype(np.float32))
    want = np.asarray(wire.unpack_sum_dense(packed, mask))
    np.testing.assert_array_equal(
        np.asarray(wire.unpack_sum_mask(packed, mask)), want)
    np.testing.assert_array_equal(
        np.asarray(wire.unpack_sum(packed, mask)), want)


@pytest.mark.parametrize("n", [247, 248, 249, 255, 256, 257])
def test_mask_popcount_acc_dtype_boundary(n):
    """The popcount path's uint8 block accumulator is only safe while the
    PADDED client count (n + (-n) % 8 pad rows) fits in 255 — the all-ones
    payload at full participation drives every per-coordinate count to its
    maximum, so any accumulator overflow shows up as a wrapped sum here.
    Regression for the old ``n <= 255`` bound, which ignored pad rows."""
    n_bytes = 64
    packed = jnp.ones((n, n_bytes), jnp.uint8) * jnp.uint8(0xFF)
    mask = jnp.ones((n,), jnp.float32)
    want = np.full(n_bytes * 8, float(n), np.float32)  # all +1 votes
    np.testing.assert_array_equal(
        np.asarray(wire.unpack_sum_mask(packed, mask)), want)
    # partial masks near the boundary stay exact too
    rng = np.random.RandomState(n)
    mask = jnp.asarray(rng.randint(0, 2, n).astype(np.float32))
    pk = _payload(rng, n, n_bytes)
    np.testing.assert_array_equal(
        np.asarray(wire.unpack_sum_mask(pk, mask)),
        np.asarray(wire.unpack_sum_dense(pk, mask)))
