"""Compressed control variates (the ``cv`` stage): SCALLION-style
compressed SCAFFOLD on the client-state substrate.

Contract under test (core/compression.py ControlVariate + the engine's
server-scope state threading):

  * state: one client-scope slot ``cv`` (per-client variate c_i, a
    (G, N, d) row tree like the EF residuals) plus one SERVER-scope slot
    ``cv_server`` (the shared variate c, ONE (d,) row in
    ServerState.comp_server — never a client axis);
  * client correction is PRE-codec: q_i = p_i - eta * (c_i - c), so the
    uplink payload is the codec's own wire format — ``cv|zsign_packed``
    ships exactly the 1 bit/coord of plain ``zsign_packed``;
  * variate updates need NO second upload: c_i += beta * m_i where m_i is
    the client's own LOCALLY-decoded payload, and the server folds
    c += beta * (n_live / N) * g_dec in _finish — exact for mean-law
    codecs because g_dec is the mean of the m_i, i.e.
    c_{t+1} - c_t == (1/N) * sum_i (c_i,t+1 - c_i,t)  (the SCAFFOLD
    bookkeeping identity, checked directly below);
  * nonlinear server decodes (sign vote/trimmed/median, topk agg=coord)
    are REFUSED at build time — the server fold would not match the sum
    of client-side updates;
  * dead clients keep BOTH their c_i rows (engine keep-state masking) and
    contribute nothing to c;
  * every cohort plan (vmap, stream at any shard size, stream devices=D,
    feed=host, async at zero latency) is bit-identical — the server
    variate is a replicated operand, the client rows shard like EF
    residuals;
  * the streamed jaxpr never computes a dense (n_total, d) f32 correction
    surface — q_i only ever exists per shard.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compression as C
from repro.core import fedavg
from repro.core.context import RoundContext
from repro.fed.sampling import CohortSampler

_DC = jax.device_count()


def _devices(d):
    return pytest.param(d, marks=pytest.mark.skipif(
        _DC < d, reason=f"needs {d} devices (have {_DC}); set "
        f"XLA_FLAGS=--xla_force_host_platform_device_count={d}"))


# ---------------------------------------------------------------------------
# build-time contract
# ---------------------------------------------------------------------------

def test_cv_declares_client_and_server_slots():
    comp = C.Pipeline("cv|zsign_packed")
    slots = {s.name: s for s in comp.state_slots(64)}
    assert slots["cv"].scope == "client"
    assert slots["cv_server"].scope == "server"
    assert slots["cv"].shape == slots["cv_server"].shape == (64,)
    assert comp.init_state(64)["cv"].shape == (64,)
    server = comp.init_server_state(64)
    assert list(server) == ["cv_server"]
    assert server["cv_server"].shape == (64,)
    assert bool(jnp.all(server["cv_server"] == 0))
    # stateless pipelines have no server tree at all
    assert C.Pipeline("zsign_packed").init_server_state(64) is None


def test_cv_spec_kwargs():
    comp = C.Pipeline("cv(eta=0.5,beta=0.25)|zsign")
    cv = comp.transforms[0]
    assert (cv.eta, cv.beta) == (0.5, 0.25)


def test_cv_refuses_nonlinear_server_decodes():
    # the variate fold is exact only when decode_sum is LINEAR in the
    # per-client local decodes; count-law aggregations are refused loudly
    for bad in ["cv|zsign(agg=vote)", "cv|zsign_packed(agg=median)",
                "cv|zsign(agg=trimmed(f=1))", "cv|topk(frac=0.1,agg=coord)"]:
        with pytest.raises(ValueError, match="control variates"):
            C.Pipeline(bad)
    # every mean-law codec composes
    for ok in ["cv|zsign", "cv|zsign_packed", "cv|qsgd", "cv|dense",
               "cv|topk(frac=0.1)", "ef|cv|zsign_packed",
               "dp(clip=1.0,noise=0.0)|cv|zsign"]:
        C.Pipeline(ok)


def test_duplicate_cv_is_a_slot_collision():
    with pytest.raises(ValueError, match="collision"):
        C.Pipeline("cv|cv|zsign_packed")


def test_encode_without_server_tree_raises():
    comp = C.Pipeline("cv|zsign_packed")
    state = comp.init_state(64)
    with pytest.raises(ValueError, match="server"):
        comp.encode(jax.random.PRNGKey(0), jnp.ones(64), state)


# ---------------------------------------------------------------------------
# the variate law, hand-checked through a lossless codec
# ---------------------------------------------------------------------------

def test_cv_dense_update_law():
    """cv|dense makes every decode exact: q = p - eta*(c_i - c) is the
    payload verbatim, c_i += beta*q, and the server fold adds
    beta*(n_live/N)*g_dec."""
    d, eta, beta = 32, 0.5, 0.25
    comp = C.Pipeline(f"cv(eta={eta},beta={beta})|dense")
    rng = np.random.RandomState(0)
    p = jnp.asarray(rng.randn(d).astype(np.float32))
    ci = jnp.asarray(rng.randn(d).astype(np.float32))
    c = jnp.asarray(rng.randn(d).astype(np.float32))
    enc, new_state = comp.encode(jax.random.PRNGKey(0), p, {"cv": ci},
                                 server={"cv_server": c})
    q = np.asarray(p - eta * (ci - c))
    np.testing.assert_allclose(np.asarray(enc)[:d], q, rtol=0, atol=0)
    np.testing.assert_allclose(np.asarray(new_state["cv"]),
                               np.asarray(ci) + beta * q, rtol=1e-6)
    g_dec = jnp.asarray(rng.randn(d).astype(np.float32))
    new_server = comp.update_server({"cv_server": c}, g_dec, 3.0, 8.0)
    np.testing.assert_allclose(np.asarray(new_server["cv_server"]),
                               np.asarray(c) + beta * (3.0 / 8.0)
                               * np.asarray(g_dec), rtol=1e-6)


def _quad_setup(spec, *, n=16, d=96, cohort="vmap", seed=5,
                round_mode=None, latency=None):
    comp = C.Pipeline(spec)
    cfg = fedavg.FedConfig(n_clients=n, client_lr=0.01, server_lr=0.3)
    kw = {"cohort": cohort}
    if round_mode is not None:
        kw.update(round_mode=round_mode, latency=latency or "zero")
    step = fedavg.build_round_step(
        lambda p, b: 0.5 * jnp.sum((p["x"] - b["y"]) ** 2), comp, cfg,
        RoundContext(**kw))
    y = jax.random.normal(jax.random.PRNGKey(seed), (1, n, 1, d))
    st = fedavg.init_server_state({"x": jnp.zeros(d)}, cfg, comp,
                                  jax.random.PRNGKey(1))
    return step, st, {"y": y}


_MASK16 = jnp.ones((1, 16)).at[0, jnp.asarray([1, 4, 5, 9, 11, 12, 13, 15])
                               ].set(0.0)


def test_cv_scaffold_bookkeeping_identity():
    """Every round: c_{t+1} - c_t == (1/N) * sum_i (c_i,t+1 - c_i,t) —
    the SCAFFOLD invariant the linear server fold was built to preserve,
    under partial participation. Exact for the dense codec; f32-close for
    the sign mean law (sum-then-scale vs scale-then-sum association)."""
    for spec, exact in [("cv|dense", True),
                        ("cv(eta=0.1,beta=0.5)|zsign_packed(z=1,sigma=0.4)",
                         False)]:
        step, st, batch = _quad_setup(spec)
        n_total = 16.0
        for _ in range(4):
            prev_rows = np.asarray(st.comp_state["cv"])
            prev_c = np.asarray(st.comp_server["cv_server"])
            st, _ = step(st, batch, _MASK16)
            lhs = np.asarray(st.comp_server["cv_server"]) - prev_c
            rhs = (np.asarray(st.comp_state["cv"])
                   - prev_rows).sum(axis=(0, 1)) / n_total
            if exact:
                np.testing.assert_allclose(lhs, rhs, rtol=0, atol=1e-7)
            else:
                np.testing.assert_allclose(lhs, rhs, rtol=2e-5, atol=1e-7)
        # dead clients KEEP their rows at zero (never computed a round)
        dead = np.asarray(st.comp_state["cv"])[0, [1, 4, 5, 9]]
        assert not dead.any()
        live = np.asarray(st.comp_state["cv"])[0, [0, 2, 3, 6]]
        assert np.abs(live).sum() > 0


def test_cv_round_one_matches_plain_codec():
    """With zero variates the round-1 correction is identically zero, so
    cv|zsign_packed's first round is BIT-identical to plain zsign_packed
    (same keys, same payloads, same server step)."""
    spec = "zsign_packed(z=1,sigma=0.7)"
    step_p, st_p, batch = _quad_setup(spec)
    step_c, st_c, _ = _quad_setup(f"cv|{spec}")
    st_p, m_p = step_p(st_p, batch, _MASK16)
    st_c, m_c = step_c(st_c, batch, _MASK16)
    np.testing.assert_array_equal(np.asarray(st_p.params["x"]),
                                  np.asarray(st_c.params["x"]))
    assert float(m_p.loss) == float(m_c.loss)


def test_ef_cv_composition_residual_law():
    """ef|cv: the EF residual closes over the FULL pre-codec input —
    including the cv correction — so EF compensates the codec error of q,
    not of p (compensating p would cancel the variate). Checked through
    the lossless dense codec: the residual is exactly zero while the
    variate still moves."""
    d = 32
    comp = C.Pipeline("ef|cv(eta=0.5,beta=1.0)|dense")
    rng = np.random.RandomState(1)
    p = jnp.asarray(rng.randn(d).astype(np.float32))
    r0 = jnp.asarray(rng.randn(d).astype(np.float32))
    ci = jnp.asarray(rng.randn(d).astype(np.float32))
    c = jnp.asarray(rng.randn(d).astype(np.float32))
    enc, new = comp.encode(jax.random.PRNGKey(0), p, {"ef": r0, "cv": ci},
                           server={"cv_server": c})
    q = np.asarray((p + r0) - 0.5 * (ci - c))
    np.testing.assert_allclose(np.asarray(enc)[:d], q, rtol=0, atol=0)
    np.testing.assert_allclose(np.asarray(new["ef"]), np.zeros(d), atol=0)
    np.testing.assert_allclose(np.asarray(new["cv"]), np.asarray(ci) + q,
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# plan equivalence: vmap == stream(any shard) == devices == host == async
# ---------------------------------------------------------------------------

def _run_plan(spec, *, rounds=3, **kw):
    step, st, batch = _quad_setup(spec, **kw)
    for _ in range(rounds):
        st, m = step(st, batch, _MASK16)
    return st


def _assert_states_equal(ref, got):
    np.testing.assert_array_equal(np.asarray(ref.params["x"]),
                                  np.asarray(got.params["x"]))
    for k in ref.comp_state:
        np.testing.assert_array_equal(np.asarray(ref.comp_state[k]),
                                      np.asarray(got.comp_state[k]),
                                      err_msg=k)
    for k in ref.comp_server:
        np.testing.assert_array_equal(np.asarray(ref.comp_server[k]),
                                      np.asarray(got.comp_server[k]),
                                      err_msg=k)


@pytest.mark.parametrize("shard", [1, 7, 64])
def test_cv_stream_bit_identical(shard):
    spec = "cv(eta=0.1,beta=0.5)|zsign_packed(z=1,sigma=0.7)"
    ref = _run_plan(spec)
    got = _run_plan(spec, cohort=f"stream(shard={shard})")
    _assert_states_equal(ref, got)


def test_cv_host_feed_bit_identical():
    spec = "cv(eta=0.1,beta=0.5)|zsign_packed(z=1,sigma=0.7)"
    ref = _run_plan(spec)
    got = _run_plan(spec, cohort="stream(shard=7,feed=host)")
    _assert_states_equal(ref, got)


@pytest.mark.parametrize("devices", [_devices(2), _devices(4)])
def test_cv_shard_map_bit_identical(devices):
    """The server variate rides into the shard_map as a REPLICATED operand
    (every device corrects with the same c); client rows shard. D devices
    are bit-identical to the vmap plan."""
    spec = "cv(eta=0.1,beta=0.5)|zsign_packed(z=1,sigma=0.7)"
    ref = _run_plan(spec)
    got = _run_plan(spec, cohort=f"stream(shard=4,devices={devices})")
    _assert_states_equal(ref, got)


def test_cv_async_zero_latency_bit_identical():
    """Zero latency + a deadline covering everyone: the async driver's
    shard pass is the sync host driver's computation exactly — cv state,
    server variate and params included."""
    spec = "cv(eta=0.1,beta=0.5)|zsign_packed(z=1,sigma=0.7)"
    ref = _run_plan(spec)
    got = _run_plan(spec, cohort="stream(shard=7)",
                    round_mode="async(deadline=100)", latency="zero")
    _assert_states_equal(ref, got)


# ---------------------------------------------------------------------------
# wire + memory pins
# ---------------------------------------------------------------------------

def test_cv_uplink_wire_unchanged():
    """cv corrects BEFORE the codec: payload pytree (shapes, dtypes) and
    the per-round uplink-bit metric are byte-for-byte those of the plain
    codec."""
    d = 4096
    plain = C.Pipeline("zsign_packed(z=1,sigma=0.5)")
    cv = C.Pipeline("cv|zsign_packed(z=1,sigma=0.5)")
    enc_plain = jax.eval_shape(
        lambda k, f: plain.encode(k, f, None)[0],
        jax.random.PRNGKey(0), jnp.zeros(d))
    enc_cv = jax.eval_shape(
        lambda k, f, s, sv: cv.encode(k, f, s, server=sv)[0],
        jax.random.PRNGKey(0), jnp.zeros(d), cv.init_state(d),
        cv.init_server_state(d))
    assert jax.tree.map(lambda a: (a.shape, str(a.dtype)), enc_plain) == \
        jax.tree.map(lambda a: (a.shape, str(a.dtype)), enc_cv)
    assert cv.wire_bits_per_coord == plain.wire_bits_per_coord == 1.0

    step_p, st_p, batch = _quad_setup("zsign_packed(z=1,sigma=0.5)")
    step_c, st_c, _ = _quad_setup("cv|zsign_packed(z=1,sigma=0.5)")
    _, m_p = step_p(st_p, batch, _MASK16)
    _, m_c = step_c(st_c, batch, _MASK16)
    assert float(m_p.uplink_bits) == float(m_c.uplink_bits)


def test_cv_stream_jaxpr_no_dense_correction_surface():
    """The streamed plan never COMPUTES an (n_total, d) f32 buffer: the
    correction q = p - eta*(c_i - c) exists only at (shard, d) inside the
    scan body. (The cv rows themselves are carried state — inherent O(n*d)
    — and only move structurally; computed surfaces are the pin.)"""
    from test_encode_fused import _max_f32_outvar_bytes, _walk_eqns
    n_total, shard = 64, 8
    d = 2 * C.ENCODE_TILE
    comp = C.Pipeline("cv(eta=0.1,beta=0.5)|zsign_packed(z=1,sigma=0.5)")
    cfg = fedavg.FedConfig(n_clients=n_total, client_lr=0.01, server_lr=0.3)
    step = fedavg.build_round_step(
        lambda p, b: 0.5 * jnp.sum((p["x"] - b["y"]) ** 2), comp, cfg,
        RoundContext(cohort=f"stream(shard={shard})"))
    st = fedavg.init_server_state({"x": jnp.zeros(d)}, cfg, comp,
                                  jax.random.PRNGKey(1))
    jaxpr = jax.make_jaxpr(step)(st, {"y": jnp.zeros((1, n_total, 1, 1))},
                                 jnp.ones((1, n_total)))
    scans = [e for e in _walk_eqns(jaxpr.jaxpr)
             if e.primitive.name == "scan"]
    assert scans, "streaming must lower to lax.scan"
    worst = max(_max_f32_outvar_bytes(e.params["jaxpr"].jaxpr)
                for e in scans)
    full_cohort = 4 * n_total * d
    assert worst < full_cohort / 4, (
        f"scan body computes a {worst}-byte f32 surface "
        f"(full cohort would be {full_cohort})")


# ---------------------------------------------------------------------------
# sampler <-> engine state-row partition agreement
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("total,shard,devices", [(16, 3, 2), (16, 4, 4),
                                                 (10, 4, 2)])
def test_partition_state_rows_matches_engine_reshard(total, shard, devices):
    """CohortSampler.partition_state_rows slices the stacked client-state
    tree exactly as the engine reshards it for stream(devices=D): same
    contiguous shard slices per device, same cyclic wrap of padded
    slots."""
    d = 5
    rows = np.arange(total * d, dtype=np.float32).reshape(1, total, d)
    cstate = {"cv": rows, "ef": -rows}
    sampler = CohortSampler(total_clients=total, per_round=total, seed=0)
    got = list(sampler.partition_state_rows(cstate, shard=shard,
                                            devices=devices))
    # the engine's reshard: flatten, cyclic-gather to padded slots, split
    n_shards = -(-total // shard)
    n_shards = -(-n_shards // devices) * devices
    slots = n_shards * shard
    per = n_shards // devices
    for k in cstate:
        flat = cstate[k].reshape(total, d)[np.arange(slots) % total]
        want = flat.reshape(n_shards, shard, d)
        for dev in range(devices):
            np.testing.assert_array_equal(
                got[dev][k], want[dev * per:(dev + 1) * per], err_msg=k)
    assert all(g["cv"].shape == (per, shard, d) for g in got)
