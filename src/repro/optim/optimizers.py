"""Minimal dependency-free optimizers (client SGD + server SGD/momentum/Adam).

API (functional, pytree-based):
    opt = make_optimizer("momentum", lr=0.05, momentum=0.9)
    state  = opt.init(params)
    params, state = opt.update(grads, state, params)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Any]  # (grads, state, params) -> (params, state)


def sgd(lr: float) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params):
        new = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
        return new, state

    return Optimizer(init, update)


def momentum(lr: float, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def update(grads, state, params):
        new_m = jax.tree.map(lambda m, g: beta * m + g.astype(jnp.float32), state, grads)
        if nesterov:
            step = jax.tree.map(lambda m, g: beta * m + g.astype(jnp.float32), new_m, grads)
        else:
            step = new_m
        new_p = jax.tree.map(lambda p, s: p - lr * s.astype(p.dtype), params, step)
        return new_p, new_m

    return Optimizer(init, update)


def adam(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        t = state["t"] + 1
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)
        new_p = jax.tree.map(
            lambda p, m_, v_: p - (lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)).astype(p.dtype),
            params, m, v)
        return new_p, {"m": m, "v": v, "t": t}

    return Optimizer(init, update)


def make_optimizer(name: str, lr: float, **kw) -> Optimizer:
    if name == "sgd":
        return sgd(lr)
    if name == "momentum":
        return momentum(lr, **kw)
    if name == "adam":
        return adam(lr, **kw)
    raise ValueError(f"unknown optimizer {name!r}")
