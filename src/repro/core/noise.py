"""z-distribution noise (Definition 1 of the paper).

p_z(t) = exp(-t^{2z}/2) / (2*eta_z),   eta_z = 2^{1/(2z)} * Gamma(1 + 1/(2z))

z=1   -> standard Gaussian.
z=inf -> Uniform[-1, 1]  (Lemma 2), with eta_inf = 1.

Sampling for finite z uses the fact that |xi_z|^{2z} ~ Gamma(shape=1/(2z),
scale=2)^... more precisely if U ~ Gamma(k=1/(2z), theta=2) then U^{1/(2z)}
with a random sign follows p_z:  p_{|xi|}(t) ∝ exp(-t^{2z}/2) on t>=0 and the
change of variables u = t^{2z} gives the Gamma density with shape 1/(2z),
scale 2.

Counter-based stream (the fused client-encode path)
---------------------------------------------------
``sample_z_noise`` draws through jax.random, which is fine when the noise
buffer may exist densely. The fused encode path (kernels/zsign +
core/compression) instead derives every random word from a COUNTER: word i of
client k is ``threefry2x32(key_k, i)``, so any tile/chunk of the stream can
be generated independently inside a Pallas grid step or a jnp chunk, with no
state and no (n_clients, d) noise buffer anywhere. Everything below
``threefry2x32`` is written in plain uint32/f32 jnp ops that lower identically
inside a Pallas TPU kernel and in ordinary XLA, which is what makes the
interpret-mode kernel and the jnp fallback bit-exact against each other.

Bit-transforms (uint32 -> noise):
  ``halves_to_u01``    word -> TWO u in (0,1): the centered 16-bit open
                       uniforms of the word's low/high halves. One
                       threefry2x32 call therefore feeds FOUR coordinates,
                       which is what makes the counter stream cheaper than
                       the jax.random draw it replaces. 16-bit resolution
                       quantizes each wire bit's Bernoulli probability by at
                       most 2^-16 ~ 1.5e-5 — orders of magnitude below the
                       estimator's own Lemma-1 bias at any practical sigma,
                       and invisible to the distribution tests.
  ``u01_to_noise``     u -> xi = F_z^{-1}(u): 2u-1 ~ Uniform(-1,1) for
                       z=inf; sqrt(2)*erfinv(2u-1) ~ N(0,1) for z=1 (the
                       inverse CDF). Box-Muller was measured first and
                       rejected: its cos/sin lower to scalar libm calls on
                       XLA CPU (~5x the cost of the threefry itself);
                       erfinv is the vectorized polynomial jax.random.normal
                       itself uses.
  Finite z > 1 has no cheap inverse CDF -> callers fall back to the dense
  ``sample_z_noise`` path (``counter_supported``).

The encoder never materializes xi at all: Sign(x + sigma*F_z^{-1}(u)) ==
[u > 1 - P_z(x/sigma)] for the symmetric z-noise CDF F_z (P_z(r) =
P(r + xi >= 0) = F_z(r), ``sign_prob``), so the fused kernels sample the
wire bit directly from its exact Bernoulli law — the inverse-CDF coupling
makes this THE SAME random variable as adding counter noise and taking the
sign, not an approximation (``stochastic_sign_bits``; equivalence verified
in tests/test_encode_fused.py).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

Z_INF = 0  # sentinel for z = +inf (uniform noise). Any z <= 0 means infinity.

#: Threefry-2x32 rounds. 13 is the smallest count that passes BigCrush for
#: this variant (Salmon et al., "Parallel random numbers: as easy as 1, 2, 3",
#: SC'11, Table 2); jax's own PRNG uses the conservative 20.
THREEFRY_ROUNDS = 13

_ROT = (13, 15, 26, 6, 17, 29, 16, 24)
_TINY = 1e-30  # safe-division floor for dynamic sigma == 0


def counter_supported(z: int) -> bool:
    """True iff the counter-based fused encode covers this z (inf or 1)."""
    return z <= Z_INF or z == 1


def client_keys(key: jax.Array, start, n: int) -> jax.Array:
    """Per-client PRNG keys by GLOBAL client index: key_j = fold_in(key, j)
    for j in [start, start + n).

    Counter-based like everything else on the encode path: client j's key
    depends only on j, never on how the round driver partitions the cohort,
    so the streaming shard scan (which derives each shard's keys from its
    global offset) and the all-clients vmap path consume IDENTICAL
    randomness — the bit-identity contract of core/fedavg.py. ``start`` may
    be a traced uint32 scalar (the shard offset inside ``lax.scan``).
    Accepts typed or raw uint32 keys and returns the same flavour, stacked
    on a leading (n,) axis.
    """
    idx = jnp.asarray(start, jnp.uint32) + jnp.arange(n, dtype=jnp.uint32)
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(idx)


def key_words(key: jax.Array):
    """PRNG key -> (k0, k1) uint32 scalar words (accepts typed or raw keys)."""
    if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
        key = jax.random.key_data(key)
    key = key.astype(jnp.uint32)
    return key[..., 0], key[..., 1]


def threefry2x32(k0, k1, x0, x1):
    """Threefry-2x32 block cipher: key (k0,k1), counter (x0,x1) -> 2 words.

    Canonical Random123 round structure: initial key injection, then rounds
    in groups of four with a subkey injection after each COMPLETED group
    (a trailing partial group, as with the 13-round variant, ends without
    an injection — matching the reference implementation's unrolling, so
    the stream is exactly the published Threefry-2x32/R).

    Plain uint32 add/xor/rotate jnp ops only, so the SAME function body runs
    inside a Pallas TPU kernel (VPU integer ops) and in ordinary jnp — the
    property the encode-equivalence tests rely on. All inputs must already be
    uint32 (scalars or broadcast-compatible arrays).
    """
    u32 = jnp.uint32
    ks2 = k0 ^ k1 ^ u32(0x1BD11BDA)
    x0 = x0 + k0
    x1 = x1 + k1
    ks = (k1, ks2, k0)
    r_idx = 0
    for i in range(5):
        group = min(4, THREEFRY_ROUNDS - r_idx)
        for _ in range(group):
            r = _ROT[r_idx % 8]
            x0 = x0 + x1
            x1 = (x1 << r) | (x1 >> (32 - r))
            x1 = x1 ^ x0
            r_idx += 1
        if group < 4:
            break
        x0 = x0 + ks[i % 3]
        x1 = x1 + ks[(i + 1) % 3] + u32(i + 1)
        if r_idx >= THREEFRY_ROUNDS:
            break
    return x0, x1


def halves_to_u01(bits):
    """uint32 word -> (u_lo, u_hi), two centered 16-bit open uniforms.

    u = (half + 0.5) / 2^16 is open at both ends (never exactly 0 or 1) and
    exactly symmetric around 1/2, so erfinv(2u-1) is always finite and 2u-1
    has mean exactly 0.
    """
    scale = jnp.float32(2.0 ** -16)
    lo = ((bits & jnp.uint32(0xFFFF)).astype(jnp.float32) + 0.5) * scale
    hi = ((bits >> 16).astype(jnp.float32) + 0.5) * scale
    return lo, hi


def u01_to_noise(u, z: int):
    """u in (0,1) -> xi = F_z^{-1}(u), the z-noise inverse CDF (z=inf or 1)."""
    xi = 2.0 * u - 1.0
    if z == 1:
        return jnp.float32(math.sqrt(2.0)) * jax.lax.erf_inv(xi)
    if z <= Z_INF:
        return xi
    raise ValueError(f"u01_to_noise covers z=inf and z=1 only, got {z}")


def counter_words(k0, k1, idx):
    """Quarter-counter array idx -> (y0, y1): 2 words = 4 u16 per counter."""
    return threefry2x32(k0, k1, idx.astype(jnp.uint32), jnp.zeros_like(idx, jnp.uint32))


def tile_u01(k0, k1, start, tile):
    """The canonical tile stream: u01 values for elements
    [start, start + tile) of client (k0,k1)'s sequence, as a flat (tile,)
    f32 array laid out in four quarters:

        [lo16(y0) | hi16(y0) | lo16(y1) | hi16(y1)],   each of tile/4,

    where (y0, y1) = threefry2x32(key, c) over the GLOBAL quarter-counters
    c = start/4 + [0, tile/4). Because the counters are global, any tiling
    of the coordinate axis — Pallas grid steps, jnp chunks, or one single
    pass — produces the identical stream; ``start`` must be a multiple of 4.
    """
    q = tile // 4
    c = jnp.uint32(start) // 4 + jax.lax.iota(jnp.uint32, q)
    y0, y1 = counter_words(k0, k1, c)
    u0, u1 = halves_to_u01(y0)
    u2, u3 = halves_to_u01(y1)
    return jnp.concatenate([u0, u1, u2, u3])


def counter_noise(key, n: int, z: int, *, tile: int = 8192) -> jax.Array:
    """(n,) z-noise values from the counter stream (F_z^{-1} of tile_u01).

    The dense-materializing view of the stream the fused encode consumes —
    used by the distribution/equivalence tests and available as a drop-in for
    ``sample_z_noise`` when bit-reproducible tiled sampling matters. ``n``
    is padded up to ``tile``; pass the same tile the encoder uses (the
    8192-element kernel tile) to reproduce its stream exactly.
    """
    if not counter_supported(z):
        raise ValueError(f"counter stream covers z=inf and z=1 only, got {z}")
    k0, k1 = key_words(key)
    n_tiles = -(-n // tile)
    u = jax.vmap(lambda t: tile_u01(k0, k1, t * tile, tile))(
        jnp.arange(n_tiles, dtype=jnp.uint32)).reshape(-1)
    return u01_to_noise(u, z)[:n]


def sign_prob(r, z: int):
    """P_z(r) = P(r + xi_z >= 0) = F_z(r), the noise CDF at r.

    z=inf: clip((r+1)/2, 0, 1);  z=1: Phi(r) = (1 + erf(r/sqrt(2)))/2.
    Pallas-safe (clip/erf lower on the VPU).
    """
    r = jnp.asarray(r, jnp.float32)
    if z <= Z_INF:
        return jnp.clip(0.5 * (r + 1.0), 0.0, 1.0)
    if z == 1:
        return 0.5 * (1.0 + jax.lax.erf(r * jnp.float32(1.0 / math.sqrt(2.0))))
    raise ValueError(f"sign_prob covers z=inf and z=1 only, got {z}")


def stochastic_sign_bits(x, u, sigma, z: int):
    """Sign(x + sigma * F_z^{-1}(u)) >= 0, computed in the compressed domain.

    ``u`` in (0,1) (one word per coordinate, e.g. ``tile_u01``); returns the
    bool wire bit. The inverse-CDF coupling [u > 1 - P_z(x/sigma)] IS the
    sign of the noisy value — the noise itself is never evaluated, which is
    what lets the encode kernels ship 1 bit/coord without an fp32 noise
    surface. ``sigma`` may be a traced scalar; sigma == 0 (static or
    runtime) degrades exactly to the noise-free Sign(x) >= 0 convention of
    ``wire.pack_flat``.
    """
    sig = jnp.asarray(sigma, jnp.float32)
    r = x * (1.0 / jnp.maximum(sig, _TINY))
    noisy = u > (1.0 - sign_prob(r, z))
    return jnp.where(sig > 0, noisy, x >= 0)


def eta_z(z: int) -> float:
    """Normalizer eta_z = 2^{1/(2z)} Gamma(1 + 1/(2z)); eta_inf = 1."""
    if z <= Z_INF:
        return 1.0
    return 2.0 ** (1.0 / (2 * z)) * math.gamma(1.0 + 1.0 / (2 * z))


def sample_z_noise(key: jax.Array, shape, z: int, dtype=jnp.float32) -> jax.Array:
    """Draw i.i.d. xi_z with p.d.f. p_z (Definition 1)."""
    if z <= Z_INF:
        return jax.random.uniform(key, shape, dtype=dtype, minval=-1.0, maxval=1.0)
    if z == 1:
        return jax.random.normal(key, shape, dtype=dtype)
    k_mag, k_sign = jax.random.split(key)
    u = jax.random.gamma(k_mag, 1.0 / (2 * z), shape, dtype=jnp.float32) * 2.0
    mag = u ** (1.0 / (2 * z))
    sign = jax.random.rademacher(k_sign, shape, dtype=jnp.int8)
    return (mag * sign).astype(dtype)


def pdf_z(t, z: int):
    """p_z(t), for tests/benchmarks."""
    t = jnp.asarray(t, jnp.float32)
    if z <= Z_INF:
        return jnp.where(jnp.abs(t) <= 1.0, 0.5, 0.0)
    return jnp.exp(-(t ** (2 * z)) / 2.0) / (2.0 * eta_z(z))


@partial(jax.jit, static_argnames=("z",))
def expected_sign(x, sigma, z: int, *, n_mc: int = 0, key=None):
    """eta_z * sigma * E[Sign(x + sigma*xi_z)], the debiased estimator mean.

    Closed form: eta_z*sigma*E[Sign(x+sigma xi)] = sigma * Psi_z(x/sigma)
    where Psi_z(x) = int_0^x exp(-t^{2z}/2) dt (paper Lemma 3 notation).
    Computed by numerical quadrature (finite z) or exactly (z=inf).
    """
    x = jnp.asarray(x, jnp.float32)
    r = x / sigma
    if z <= Z_INF:
        return sigma * jnp.clip(r, -1.0, 1.0)
    # Gauss-Legendre style quadrature of Psi_z on [0, r] via substitution
    # t = r*u, u in [0,1]:   Psi_z(r) = r * int_0^1 exp(-(r*u)^{2z}/2) du.
    n = 256
    u = (jnp.arange(n, dtype=jnp.float32) + 0.5) / n
    integ = jnp.mean(jnp.exp(-((r[..., None] * u) ** (2 * z)) / 2.0), axis=-1)
    return sigma * r * integ
