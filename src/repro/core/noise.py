"""z-distribution noise (Definition 1 of the paper).

p_z(t) = exp(-t^{2z}/2) / (2*eta_z),   eta_z = 2^{1/(2z)} * Gamma(1 + 1/(2z))

z=1   -> standard Gaussian.
z=inf -> Uniform[-1, 1]  (Lemma 2), with eta_inf = 1.

Sampling for finite z uses the fact that |xi_z|^{2z} ~ Gamma(shape=1/(2z),
scale=2)^... more precisely if U ~ Gamma(k=1/(2z), theta=2) then U^{1/(2z)}
with a random sign follows p_z:  p_{|xi|}(t) ∝ exp(-t^{2z}/2) on t>=0 and the
change of variables u = t^{2z} gives the Gamma density with shape 1/(2z),
scale 2.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

Z_INF = 0  # sentinel for z = +inf (uniform noise). Any z <= 0 means infinity.


def eta_z(z: int) -> float:
    """Normalizer eta_z = 2^{1/(2z)} Gamma(1 + 1/(2z)); eta_inf = 1."""
    if z <= Z_INF:
        return 1.0
    return 2.0 ** (1.0 / (2 * z)) * math.gamma(1.0 + 1.0 / (2 * z))


def sample_z_noise(key: jax.Array, shape, z: int, dtype=jnp.float32) -> jax.Array:
    """Draw i.i.d. xi_z with p.d.f. p_z (Definition 1)."""
    if z <= Z_INF:
        return jax.random.uniform(key, shape, dtype=dtype, minval=-1.0, maxval=1.0)
    if z == 1:
        return jax.random.normal(key, shape, dtype=dtype)
    k_mag, k_sign = jax.random.split(key)
    u = jax.random.gamma(k_mag, 1.0 / (2 * z), shape, dtype=jnp.float32) * 2.0
    mag = u ** (1.0 / (2 * z))
    sign = jax.random.rademacher(k_sign, shape, dtype=jnp.int8)
    return (mag * sign).astype(dtype)


def pdf_z(t, z: int):
    """p_z(t), for tests/benchmarks."""
    t = jnp.asarray(t, jnp.float32)
    if z <= Z_INF:
        return jnp.where(jnp.abs(t) <= 1.0, 0.5, 0.0)
    return jnp.exp(-(t ** (2 * z)) / 2.0) / (2.0 * eta_z(z))


@partial(jax.jit, static_argnames=("z",))
def expected_sign(x, sigma, z: int, *, n_mc: int = 0, key=None):
    """eta_z * sigma * E[Sign(x + sigma*xi_z)], the debiased estimator mean.

    Closed form: eta_z*sigma*E[Sign(x+sigma xi)] = sigma * Psi_z(x/sigma)
    where Psi_z(x) = int_0^x exp(-t^{2z}/2) dt (paper Lemma 3 notation).
    Computed by numerical quadrature (finite z) or exactly (z=inf).
    """
    x = jnp.asarray(x, jnp.float32)
    r = x / sigma
    if z <= Z_INF:
        return sigma * jnp.clip(r, -1.0, 1.0)
    # Gauss-Legendre style quadrature of Psi_z on [0, r] via substitution
    # t = r*u, u in [0,1]:   Psi_z(r) = r * int_0^1 exp(-(r*u)^{2z}/2) du.
    n = 256
    u = (jnp.arange(n, dtype=jnp.float32) + 0.5) / n
    integ = jnp.mean(jnp.exp(-((r[..., None] * u) ** (2 * z)) / 2.0), axis=-1)
    return sigma * r * integ
