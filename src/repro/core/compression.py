"""Gradient compressors over the flat wire-buffer codec (core/wire.py).

The paper's contribution (ZSignCompressor) plus every baseline it compares
against: vanilla SignSGD, EF-SignSGD, Sto-SignSGD, QSGD/FedPAQ, top-k, DP
Gaussian, and identity (uncompressed FedAvg). All compressors share one
flat-buffer interface so the federated round engine (core/fedavg.py) treats
them as a plug-in:

    init_state(n_coords)              -> per-client residual buffer or None
    encode(key, flat, state, sigma)   -> (payload, new_state)  # on the client
    aggregate(payload, mask, n_coords)-> (d_pad,) f32 masked SUM  # server
    decode_mean(flat_mean, sigma)     -> (d_pad,) f32 estimate    # server
    wire_format()                     -> WireFormat (dtype, bits/coord, layout)

``flat`` is the pseudo-gradient ((x_{t-1} - x^i_{t,E}) / gamma) flattened
ONCE by the engine into a single fp32 buffer; ``payload`` is what crosses the
network: a bitpacked uint8 buffer for every sign-family compressor (zsign,
zsign_packed, stosign, efsign — 1 bit per coordinate, 32x smaller than fp32),
a COO (values, indices) pair for top-k, dense fp32 otherwise.

``aggregate`` consumes payloads stacked on a leading client axis together
with the (n_clients,) participation mask and returns the masked flat SUM.
All decoders are linear in the per-client encodings, so the server may
aggregate one parallel group per collective or scan-accumulate sums across
sequential client groups — both paths produce identical estimates.

Every sign-family ``aggregate`` (zsign, zsign_packed, stosign, and efsign,
whose weights are ``mask * scale``) reduces DIRECTLY in the compressed
domain through :func:`sign_reduce`: one fused weighted sign-reduce over the
stacked (n_clients, n_bytes) uint8 payload, selected by the compressor's
``agg_backend`` field ("auto" picks the Pallas kernel on TPU and the
LUT-gather jnp path elsewhere; "pallas"/"jnp" force one; "dense" is the
legacy dense-sign-matrix path kept only for benchmarks/tests). The server's
per-round memory traffic is therefore ~1 bit/coord/client instead of the
32 bits/coord/client the old vmap(unpack_signs) + einsum decode cost. The
engine (core/fedavg.py) and launchers thread ``agg_backend`` through
``build_round_step`` so deployments can pin a backend without rebuilding
compressors.

The client encode side mirrors the server: every sign-family encode streams
through a FUSED path selected by ``encode_backend`` ("auto" | "jnp" |
"pallas" | "reference"). The fused paths derive their noise from a counter
(threefry2x32 of the client key and the global element index — core/noise.py)
and sample each wire bit directly from its exact Bernoulli law
[u > 1 - P_z(x/sigma)] (the inverse-CDF coupling: identically distributed to
Sign(x + sigma*xi_z), not an approximation), so the (d,) fp32 noise buffer —
which the vmap over clients used to stack into an (n_clients, d) HBM surface
32x the wire size — never exists. "pallas" generates the randomness inside
each kernel grid tile (kernels/zsign ``zsign_encode_fused``; what the old
"on real TPU the noise would be generated in-kernel" note promised, now
real); "jnp" is ``fused_sign_encode_jnp``, bit-exact against the kernel for
the same key (single elementwise fusion by default — XLA allocates no f32
temp, verified by compiled-memory tests — or an explicitly chunked scan via
``encode_chunk_tiles`` that bounds the live noise window to a few tiles);
"auto" picks pallas on TPU, jnp elsewhere; "reference" keeps the dense
jax.random draw as the statistical oracle. Finite z > 1 has no cheap inverse
CDF and always takes the dense path. Sto-Sign reuses the z=inf fused path
with sigma = ||flat|| computed as a prior reduction.

Wire-size accounting: ``wire_bits_per_coord`` (mirrored in ``wire_format()``)
is the logical uplink cost per model coordinate and is derived from the
compressor's own hyper-parameters (e.g. 64*frac for top-k, ceil(log2(2s+1))
for QSGD) — metrics multiply it by the true coordinate count, never by the
padded buffer length. Fused-encode payloads are tile-padded
(ceil(d/8192)*1024 bytes, like the Pallas kernel); the logical cost stays
1 bit/coord.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core import noise as znoise
from repro.core import wire
from repro.core.wire import (WireFormat, pack_flat, pack_signs,
                             unpack_signs, unpack_sum)

__all__ = [
    "Compressor", "ZSignCompressor", "StoSignCompressor", "EFSignCompressor",
    "QSGDCompressor", "TopKCompressor", "DPGaussianCompressor",
    "PackedZSignCompressor", "make_compressor", "available", "global_norm",
    "pack_signs", "unpack_signs", "sign_reduce", "fused_sign_encode_jnp",
    "AGG_BACKENDS", "ENCODE_BACKENDS",
]

#: aggregation backends for the sign-family weighted reduce
AGG_BACKENDS = ("auto", "jnp", "pallas", "dense")

#: client-encode backends for the sign family ("reference" = dense draw)
ENCODE_BACKENDS = ("auto", "jnp", "pallas", "reference")

#: fused-encode tile, in elements. MUST equal kernels/zsign ops.TILE — the
#: jnp fallback reproduces the kernel's per-tile counter stream (asserted in
#: tests without importing the Pallas stack here).
ENCODE_TILE = 8192


def _resolve_encode_backend(backend: str) -> str:
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend not in ("jnp", "pallas", "reference"):
        raise ValueError(f"unknown encode backend {backend!r}; "
                         f"expected one of {ENCODE_BACKENDS}")
    return backend


def fused_sign_encode_jnp(flat: jax.Array, key, sigma, *, z: int,
                          add_noise: bool = True,
                          chunk_tiles: int = 0) -> jax.Array:
    """Counter-based fused encode, pure jnp — bit-exact vs the Pallas kernel.

    (d,) f32 -> tile-padded bitpacked uint8 (ceil(d/8192)*1024 bytes), the
    identical byte stream ``kernels/zsign ops.zsign_encode_fused`` produces
    for the same key (same global element counters, same per-tile word
    layout, same f32 threshold math — see noise.tile_u01 /
    noise.stochastic_sign_bits).

    ``chunk_tiles == 0`` (default): one elementwise pass. The jaxpr shows a
    (d_pad,) f32 uniform intermediate, but XLA fuses the whole
    threefry -> threshold -> bitpack chain into the uint8 output — compiled
    temp allocation is ~0 bytes where the dense draw allocates 2 x 4d
    (pinned by tests/test_encode_fused.py), and it is the fastest CPU path.

    ``chunk_tiles > 0``: lax.scan over chunks of that many 8192-element
    tiles, bounding even the jaxpr-level live window to
    (chunk_tiles * 8192,) f32 per client — the memory-guarantee-by-
    construction variant (scan carries ~30-80ms of loop overhead per round
    on small CPUs, so it is opt-in rather than the default).
    """
    d = flat.shape[0]
    tile = ENCODE_TILE
    n_tiles = -(-d // tile)
    dpad = n_tiles * tile
    flat = jnp.pad(flat.astype(jnp.float32), (0, dpad - d))
    if not add_noise:
        return pack_flat(flat)
    k0, k1 = znoise.key_words(key)

    def tiles_packed(x_chunk, first_tile, n):
        u = jax.vmap(lambda t: znoise.tile_u01(k0, k1, t * tile, tile))(
            first_tile + jnp.arange(n, dtype=jnp.uint32)).reshape(-1)
        return wire.pack_bool(znoise.stochastic_sign_bits(x_chunk, u, sigma, z))

    if chunk_tiles <= 0 or n_tiles <= chunk_tiles:
        return tiles_packed(flat, jnp.uint32(0), n_tiles)
    n_chunks = -(-n_tiles // chunk_tiles)
    cpad = n_chunks * chunk_tiles * tile - dpad
    x2 = jnp.pad(flat, (0, cpad)).reshape(n_chunks, chunk_tiles * tile)
    starts = jnp.arange(n_chunks, dtype=jnp.uint32) * jnp.uint32(chunk_tiles)
    _, packed = jax.lax.scan(
        lambda _, xs: (None, tiles_packed(xs[0], xs[1], chunk_tiles)),
        None, (x2, starts))
    return packed.reshape(-1)[: dpad // 8]


def sign_reduce(packed: jax.Array, weights: jax.Array,
                backend: str = "auto", *,
                weights_are_mask: bool = False) -> jax.Array:
    """Weighted sign-reduce over stacked bitpacked payloads.

    (n_clients, n_bytes) u8 + (n_clients,) f32 -> (8*n_bytes,) f32 weighted
    sum of the +/-1 signs, without ever materializing the dense
    (n_clients, d) fp32 sign matrix. Correct for ARBITRARY per-client
    weights on every backend (0/1 participation masks, data-size
    proportional weights, EF mask * scale). ``backend``:

      auto    Pallas kernel on TPU, wire.unpack_sum elsewhere (the CPU
              LUT-gather path, bit-identical to the kernel)
      pallas  force the fused kernel (interpret mode off-TPU)
      jnp     force wire.unpack_sum
      dense   legacy dense-matrix path (wire.unpack_sum_dense) — oracle and
              benchmark baseline only

    ``weights_are_mask`` is a STATIC caller guarantee that every weight is
    0 or 1 (a participation mask). The membership contract cannot be checked
    on traced values, so it is plumbed from whoever constructs the mask (the
    round engine via ``build_round_step(weights_are_mask=True)``); when set,
    the jnp backend dispatches to the popcount specialization
    ``wire.unpack_sum_mask`` (bit-identical for any 0/1 mask — integer
    sums). Weighted/EF calls keep the LUT path.
    """
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend == "pallas":
        from repro.kernels.zsign import ops as K
        return K.sign_reduce(packed, weights)
    if backend == "dense":
        return wire.unpack_sum_dense(packed, weights)
    if backend != "jnp":
        raise ValueError(f"unknown agg backend {backend!r}; "
                         f"expected one of {AGG_BACKENDS}")
    if weights_are_mask:
        return wire.unpack_sum_mask(packed, weights)
    return unpack_sum(packed, weights)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
            for l in jax.tree_util.tree_leaves(tree)))


# ---------------------------------------------------------------------------
# compressors
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Compressor:
    """Base: identity (uncompressed FedAvg). Dense fp32 wire format."""
    wire_bits_per_coord: float = 32.0
    name: str = "identity"

    def wire_format(self) -> WireFormat:
        return WireFormat("float32", self.wire_bits_per_coord, "dense")

    def init_state(self, n_coords: int) -> Any:
        return None

    def encode(self, key, flat: jax.Array, state, sigma=None) -> Tuple[Any, Any]:
        del key, sigma
        return flat, state

    def decode_mean(self, flat_mean: jax.Array, sigma=None) -> jax.Array:
        del sigma
        return flat_mean

    def aggregate(self, payload, mask: jax.Array, n_coords: int) -> jax.Array:
        """Masked SUM over the leading client axis of stacked payloads.

        ``n_coords`` is the true (unpadded) coordinate count from the
        engine's TreeSpec — sparse layouts need it to materialize the dense
        sum; others may ignore it and return padded buffers.
        Default: dense einsum (one fp32 collective)."""
        del n_coords
        return jnp.einsum("nd,n->d", payload.astype(jnp.float32), mask)

    def stacks_group_payloads(self) -> bool:
        """Whether the engine's sequential-group scan should emit the raw
        payload stack (aggregated ONCE over all groups x clients at the end)
        instead of accumulating per-group decoded f32 sums.

        True exactly when the wire layout is compressed (bitpacked signs,
        COO top-k): the stacked payloads are then far smaller than
        client_groups dense f32 partials, and the whole cross-group
        reduction happens in the compressed domain. Dense fp32 layouts keep
        the accumulate-in-scan path, whose live state is one (d,) buffer.
        """
        return self.wire_format().layout != "dense"


@dataclasses.dataclass(frozen=True)
class ZSignCompressor(Compressor):
    """The paper's stochastic sign operator (Algorithm 1, line 11).

    enc = Sign(flat + sigma * xi_z)  with xi_z ~ p_z  (z<=0 means z = +inf),
    transmitted as a bitpacked uint8 buffer (8 coords/byte — the TRUE 1-bit
    uplink). decode scales by eta_z * sigma — the asymptotically-unbiased
    estimator of Lemma 1. sigma == 0.0 recovers vanilla SignSGD (biased;
    diverges on the paper's counterexample — reproduced in tests), with the
    noise draw gated off entirely on every backend.

    ``encode_backend`` selects the client-side path (module docstring): the
    fused counter-based encoders for z in {inf, 1} ("auto"/"jnp"/"pallas",
    all bit-exact against each other for the same key), or the dense
    jax.random draw ("reference", and always for finite z > 1).
    """
    z: int = 1
    sigma: float = 0.01
    wire_bits_per_coord: float = 1.0
    name: str = "zsign"
    agg_backend: str = "auto"   # sign_reduce backend for server aggregation
    encode_backend: str = "auto"    # client fused-encode backend
    encode_chunk_tiles: int = 0     # >0: chunked-scan jnp fallback window
    weights_are_mask: bool = False  # engine guarantee: weights are 0/1

    def wire_format(self) -> WireFormat:
        return WireFormat("uint8", self.wire_bits_per_coord, "bitpacked")

    def _encode_dense(self, key, flat, sig, add_noise):
        """Dense-draw statistical oracle (and the finite z > 1 path)."""
        if add_noise:
            flat = flat + sig * znoise.sample_z_noise(key, flat.shape, self.z)
        return pack_flat(flat)

    def encode(self, key, flat, state, sigma=None):
        # the ONE place the noise gate is decided: a static sigma of 0.0
        # (vanilla SignSGD) disables the draw on every backend; a dynamic
        # sigma (sigma is not None, possibly traced) always flows through —
        # a runtime 0 degrades exactly inside stochastic_sign_bits.
        add_noise = (sigma is not None) or self.sigma > 0.0
        sig = self.sigma if sigma is None else sigma
        backend = _resolve_encode_backend(self.encode_backend)
        if backend == "reference" or (add_noise
                                      and not znoise.counter_supported(self.z)):
            return self._encode_dense(key, flat, sig, add_noise), state
        if backend == "pallas":
            from repro.kernels.zsign import ops as K
            return K.zsign_encode_fused(flat, key, sig, z=self.z,
                                        add_noise=add_noise), state
        return fused_sign_encode_jnp(flat, key, sig, z=self.z,
                                     add_noise=add_noise,
                                     chunk_tiles=self.encode_chunk_tiles), state

    def aggregate(self, payload, mask, n_coords):
        del n_coords
        return sign_reduce(payload, mask, self.agg_backend,
                           weights_are_mask=self.weights_are_mask)

    def decode_mean(self, flat_mean, sigma=None):
        if sigma is None:
            scale = znoise.eta_z(self.z) * self.sigma if self.sigma > 0.0 else 1.0
        else:
            scale = znoise.eta_z(self.z) * sigma
        return flat_mean * scale


@dataclasses.dataclass(frozen=True)
class StoSignCompressor(Compressor):
    """Sto-SignSGD [Safaryan & Richtarik '21] as unified by the paper:
    z = inf with the *input-dependent* noise scale sigma_i = ||flat_i||_2.
    Bitpacked 1-bit wire format. The fused encode backends reuse the z=inf
    counter path with sigma = ||flat|| computed as a prior reduction (the
    norm is a traced scalar; the threshold kernel takes dynamic sigma), so
    this baseline also never materializes a dense noise buffer."""
    wire_bits_per_coord: float = 1.0
    name: str = "stosign"
    agg_backend: str = "auto"
    encode_backend: str = "auto"
    encode_chunk_tiles: int = 0
    weights_are_mask: bool = False

    def wire_format(self) -> WireFormat:
        return WireFormat("uint8", self.wire_bits_per_coord, "bitpacked")

    def encode(self, key, flat, state, sigma=None):
        del sigma
        nrm = jnp.linalg.norm(flat)
        backend = _resolve_encode_backend(self.encode_backend)
        if backend == "reference":
            xi = jax.random.uniform(key, flat.shape, minval=-1.0, maxval=1.0)
            return pack_flat(flat + nrm * xi), state
        if backend == "pallas":
            from repro.kernels.zsign import ops as K
            return K.zsign_encode_fused(flat, key, nrm, z=znoise.Z_INF), state
        return fused_sign_encode_jnp(flat, key, nrm, z=znoise.Z_INF,
                                     chunk_tiles=self.encode_chunk_tiles), state

    def aggregate(self, payload, mask, n_coords):
        del n_coords
        return sign_reduce(payload, mask, self.agg_backend,
                           weights_are_mask=self.weights_are_mask)

    def decode_mean(self, flat_mean, sigma=None):
        # majority-vote style: server applies its own stepsize to mean sign.
        del sigma
        return flat_mean


@dataclasses.dataclass(frozen=True)
class EFSignCompressor(Compressor):
    """EF-SignSGD [Karimireddy et al. '19]: scaled sign + per-client residual.

    enc_i = (||p_i||_1 / d) * Sign(p_i),  p_i = flat_i + e_i ;
    e_i <- p_i - enc_i.  The wire payload is the bitpacked sign buffer plus
    ONE fp32 scale (d + 32 bits total, so bits/coord -> 1 as d grows). The
    residual state is a single flat fp32 buffer per client. Stale residuals
    under partial participation are kept exactly (engine masks the state
    update) — matching the paper's related-work discussion of EF's
    partial-participation limitation.
    """
    wire_bits_per_coord: float = 1.0
    name: str = "efsign"
    use_kernel: bool = False   # fused Pallas EF step (kernels/efsign)
    agg_backend: str = "auto"

    def wire_format(self) -> WireFormat:
        return WireFormat("uint8", self.wire_bits_per_coord, "bitpacked+scale")

    def init_state(self, n_coords: int):
        return jnp.zeros((n_coords,), jnp.float32)

    def encode(self, key, flat, state, sigma=None):
        del key, sigma
        p = flat + state
        scale = jnp.mean(jnp.abs(p))
        if self.use_kernel:
            # one fused VMEM pass: bitpacked payload + residual together
            from repro.kernels.efsign import ops as EK
            packed, res = EK.ef_sign_encode(flat, state, scale)
        else:
            # residual uses the same p >= 0 sign convention as the wire
            # payload, so EF accounts exactly for what the server decodes
            # (jnp.sign's 0-at-0 would leak +scale per round on zero coords)
            packed = pack_flat(p)
            res = p - scale * jnp.where(p >= 0, 1.0, -1.0)
        return {"packed": packed, "scale": scale}, res

    def aggregate(self, payload, mask, n_coords):
        # weights = mask * per-client scale: the fused reduce handles the
        # scale-weighted sum directly in the compressed domain.
        del n_coords
        return sign_reduce(payload["packed"], mask * payload["scale"],
                           self.agg_backend)

    def decode_mean(self, flat_mean, sigma=None):
        del sigma
        return flat_mean


@dataclasses.dataclass(frozen=True)
class QSGDCompressor(Compressor):
    """Unbiased stochastic quantizer of Alistarh et al. (paper Definition 2);
    with FedAvg local steps this is FedPAQ/FedCOM. ``s`` quantization levels;
    wire cost derives from s: ceil(log2(2s+1)) bits/coord (+ one fp32 norm,
    amortized)."""
    s: int = 1
    wire_bits_per_coord: float = 2.0
    name: str = "qsgd"

    def __post_init__(self):
        object.__setattr__(self, "wire_bits_per_coord",
                           float(math.ceil(math.log2(2 * self.s + 1))))

    def encode(self, key, flat, state, sigma=None):
        del sigma
        nrm = jnp.linalg.norm(flat) + 1e-12
        r = jnp.abs(flat) / nrm * self.s
        low = jnp.floor(r)
        up = jax.random.bernoulli(key, jnp.clip(r - low, 0.0, 1.0), flat.shape)
        lvl = (low + up.astype(jnp.float32)) / self.s
        return nrm * jnp.sign(flat) * lvl, state

    def decode_mean(self, flat_mean, sigma=None):
        del sigma
        return flat_mean


@dataclasses.dataclass(frozen=True)
class TopKCompressor(Compressor):
    """Beyond-paper sparsifier baseline: keep the top-k fraction of the flat
    buffer by magnitude (GLOBAL top-k across all tensors) with per-client
    error feedback. COO wire format: (values, indices), 64*frac bits/coord.

    Selection runs as a two-stage chunked top-k when d exceeds ``chunk``:
    per-chunk ``lax.top_k`` candidates, then a final top-k over the
    candidate pool — O(d log k / chunk)-ish work instead of one full-buffer
    sort-like pass over all d coordinates, and exactly equivalent to the
    single-stage selection (every global top-k element is in its own chunk's
    top-k; tie-breaking by lowest index is preserved because candidates are
    ordered by (chunk, rank) — verified exhaustively in tests).
    """
    frac: float = 0.01
    chunk: int = 65536  # two-stage selection above this many coordinates
    wire_bits_per_coord: float = 0.64  # overwritten in __post_init__
    name: str = "topk"

    def __post_init__(self):
        # fp32 value + int32 index per kept coordinate.
        object.__setattr__(self, "wire_bits_per_coord", 64.0 * self.frac)

    def wire_format(self) -> WireFormat:
        return WireFormat("float32", self.wire_bits_per_coord, "sparse_coo")

    def init_state(self, n_coords: int):
        return jnp.zeros((n_coords,), jnp.float32)

    def _select(self, score: jax.Array, k: int) -> jax.Array:
        """Indices of the k largest scores (ties -> lowest index first)."""
        d = score.shape[0]
        if d <= self.chunk or k >= self.chunk:
            _, idx = jax.lax.top_k(score, k)
            return idx
        n_chunks = -(-d // self.chunk)
        pad = n_chunks * self.chunk - d
        s = jnp.pad(score, (0, pad), constant_values=-jnp.inf)
        cand_val, cand_idx = jax.lax.top_k(s.reshape(n_chunks, self.chunk), k)
        base = (jnp.arange(n_chunks, dtype=cand_idx.dtype)[:, None]
                * self.chunk)
        cand_idx = (cand_idx + base).reshape(-1)
        _, sel = jax.lax.top_k(cand_val.reshape(-1), k)
        return cand_idx[sel]

    def encode(self, key, flat, state, sigma=None):
        del key, sigma
        p = flat + state
        k = max(1, int(p.shape[0] * self.frac))
        idx = self._select(jnp.abs(p), k)
        return {"values": p[idx], "indices": idx}, p.at[idx].set(0.0)

    def aggregate(self, payload, mask, n_coords):
        # scatter-add each client's COO payload into the dense flat space.
        vals = (payload["values"] * mask[:, None]).reshape(-1)
        idx = payload["indices"].reshape(-1)
        return jnp.zeros((n_coords,), jnp.float32).at[idx].add(vals)

    def decode_mean(self, flat_mean, sigma=None):
        del sigma
        return flat_mean


@dataclasses.dataclass(frozen=True)
class DPGaussianCompressor(Compressor):
    """Uncompressed DP-FedAvg mechanism: transmit flat + N(0, sigma^2 I)
    (clipping happens in the round engine via cfg.dp_clip). 32 bits/coord."""
    sigma: float = 1.0
    wire_bits_per_coord: float = 32.0
    name: str = "dpgauss"

    def encode(self, key, flat, state, sigma=None):
        sig = self.sigma if sigma is None else sigma
        return flat + sig * jax.random.normal(key, flat.shape), state


@dataclasses.dataclass(frozen=True)
class PackedZSignCompressor(ZSignCompressor):
    """z-sign pinned to the Pallas TPU kernels (kernels/zsign): encode
    generates its noise IN-KERNEL from the per-(client, tile) counter stream
    and fuses threshold + sign + 8:1 bitpack into one VMEM pass
    (``zsign_encode_fused``; default ``encode_backend="pallas"``, interpret
    mode off-TPU); server aggregation is the fused ``sign_reduce`` weighted
    reduce (one kernel launch for the whole client stack — inherited from
    ZSignCompressor). Wire bytes are bit-for-bit identical to the jnp fused
    path for the same key (verified in tests). The dense-noise kernel
    (``zsign_compress``, noise as an HBM input) remains the "reference"
    backend and the finite z > 1 path; its sigma == 0 mode skips the noise
    draw entirely instead of drawing and discarding a full dense buffer.
    Payload is uint8 of ceil(d/8192)*1024 bytes (kernel tile padding; the
    logical cost stays 1 bit/coord — see wire.py accounting notes).
    """
    name: str = "zsign_packed"
    encode_backend: str = "pallas"

    def _encode_dense(self, key, flat, sig, add_noise):
        from repro.kernels.zsign import ops as K
        if not add_noise:
            # vanilla-SignSGD mode: no noise is drawn (flat doubles as a
            # dummy operand; sigma == 0 makes it a no-op inside the kernel)
            return K.zsign_compress(flat, flat, 0.0)
        noise = znoise.sample_z_noise(key, flat.shape, self.z)
        return K.zsign_compress(flat, noise, sig)


_REGISTRY = {
    "identity": Compressor,
    "zsign": ZSignCompressor,
    "stosign": StoSignCompressor,
    "efsign": EFSignCompressor,
    "qsgd": QSGDCompressor,
    "topk": TopKCompressor,
    "dpgauss": DPGaussianCompressor,
    "zsign_packed": PackedZSignCompressor,
}


def available() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def make_compressor(name: str, **kw) -> Compressor:
    return _REGISTRY[name](name=name, **kw)
