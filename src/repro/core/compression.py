"""Composable compression pipelines over the flat wire-buffer codec (wire.py).

The paper's central claim is that stochastic sign compression is ONE scheme
with many instances (sto-sign is the z -> inf member, DP mechanisms compose
with sign transmission, error feedback wraps any contractive codec). This
module makes the code shape match the math shape: a compressor is a
:class:`Pipeline` of orthogonal stages rather than a bespoke class per
combination.

Stage taxonomy
--------------

``Transform`` stages are codec-agnostic pre-processing on the flat fp32
buffer, applied client-side before anything touches the wire:

  ``ef``              error-feedback residual (Karimireddy et al. '19): adds
                      the per-client residual before the codec and records
                      what the codec failed to transmit. Stateful: one flat
                      fp32 slot ("ef") per client.
  ``cv``              compressed SCAFFOLD control variates (SCALLION,
                      arXiv:2308.08165): per-client variate c_i (slot "cv")
                      plus a SHARED server variate c (server-scope slot
                      "cv_server"); pre-codec drift correction
                      p - eta*(c_i - c), variate updates from the locally
                      decoded payload — the heterogeneity fix at ZERO extra
                      wire cost. ``cv|zsign_packed`` is compressed SCAFFOLD
                      at 1 bit/coord.
  ``sigma_sched``     per-layer sigma schedule (paper §5 "layer-wise sigma"):
                      a STATIC geometric ramp of per-leaf multipliers m_j
                      from ``head`` to ``tail`` applied to the flat buffer
                      before the codec. For sign codecs
                      Sign(m_j*p + sigma*xi) == Sign(p + (sigma/m_j)*xi), so
                      scaling the buffer IS running layer j at effective
                      noise sigma/m_j — one scalar codec sigma, per-layer
                      effect. Stateless; the server decode divides the
                      estimate by m. Needs the round's TreeSpec
                      (``needs_tree_spec``) to map leaves to coordinate
                      ranges; must be the first stage and cannot compose
                      with ``cv``.
  ``dp``              DP clip + Gaussian noise (paper Algorithm 2): clips the
                      buffer to norm ``clip`` and adds ``noise`` * N(0, I).
                      When the pipeline's codec is a sign codec the noise is
                      FUSED into the codec's sigma (the same Gaussian does
                      double duty: privacy and the Lemma-1 sign-bias
                      correction), so the dense noise buffer never exists and
                      the wire stays 1 bit/coord. ``dp(clip=1.0,eps=2.0)``
                      calibrates the noise from a target (eps, delta) via the
                      RDP accountant in core/dp.py.

``WireCodec`` stages own the :class:`~repro.core.wire.WireFormat` and BOTH
ends of the wire: the client encode and the server's compressed-domain
``aggregate`` (sign codecs reduce bitpacked payloads through
:func:`sign_reduce` without ever materializing the dense (n_clients, d) sign
matrix; the COO codec scatter-adds):

  ``zsign``           the paper's stochastic sign operator: bitpacked
                      Sign(x + sigma * xi_z) at 1 bit/coord, counter-based
                      fused encode for z in {inf, 1}. ``sigma`` is an
                      EXPLICIT field (default 0.0 = vanilla SignSGD, which
                      statically gates off the PRNG on every backend);
                      ``sigma_mode="norm"`` is the sto-sign instance
                      (sigma_i = ||flat_i||), ``scale="mean_abs"`` transmits
                      the EF-SignSGD per-client magnitude next to the bits.
  ``zsign_packed``    same codec pinned to the Pallas TPU kernels (in-kernel
                      counter noise; dense-reference path through
                      ``zsign_compress``). sigma == 0 keeps the no-PRNG
                      jaxpr guarantee (regression-pinned in tests).
  ``stosign``         alias: ``zsign(sigma_mode=norm, z=inf)``.
  ``qsgd``            unbiased stochastic quantizer (Alistarh et al.),
                      dense fp32 wire of ceil(log2(2s+1)) logical bits/coord.
  ``topk``            global top-k sparsifier, COO (values, indices) wire;
                      STATELESS — compose ``ef|topk`` for the classic
                      residual-corrected variant.
  ``identity``/``dense``  uncompressed fp32 FedAvg.

A :class:`Pipeline` is transforms + one codec, buildable from a spec string:

    Pipeline("ef|zsign")                        # == EF-SignSGD, bit-exact
    Pipeline("zsign(z=1,sigma=0.5)")            # the paper's 1-SignFedAvg
    Pipeline("dp(clip=1.0,eps=2.0)|zsign_packed")  # DP at 1 bit/coord
    Pipeline("ef|topk(frac=0.01)")              # EF over sparsification

and exposes the engine-facing compressor interface (core/fedavg.py consumes
it unchanged):

    init_state(n_coords)              -> keyed per-client state dict
                                         ({slot_name: buffer}) or None
    init_server_state(n_coords)       -> keyed SHARED server state dict
                                         (control variates) or None
    encode(key, flat, state, sigma,
           server, spec)             -> (payload, new_state)  # client
    update_server(server, g_dec,
                  n_live, n_total)    -> new server state       # round tail
    aggregate(payload, mask, n_coords)-> masked SUM accumulator   # server
                                         ((d_pad,) f32, or the (2, d_pad)
                                         int32 vote pair for robust agg=)
    decode_sum(enc_sum, n_live,
               sigma, spec)          -> (d_pad,) f32 estimate    # server
    decode_mean(flat_mean, sigma,
                spec)                -> (d_pad,) f32 estimate (mean law)
    wire_format()                     -> WireFormat (dtype, bits/coord, ...)

``flat`` is the pseudo-gradient flattened ONCE by the engine
(wire.TreeSpec); ``spec`` is that TreeSpec, passed exactly when the
pipeline declares ``needs_tree_spec`` (sigma_sched); ``payload`` is what
crosses the network. ``aggregate``
consumes payloads stacked on a leading client axis with the (n_clients,)
participation mask; all decoders are linear in the per-client encodings, so
group-sum aggregation across sequential client groups is exact.

State composition contract: every STATEFUL stage declares named slots
through ``state_spec(n_coords)`` (``fed/client_state.StateSlot``); the
pipeline's client state is the keyed dict ``{slot_name: buffer}`` and slot
names must be unique across stages (collision -> build-time error). A
stateful stage participates in ``encode`` through two hooks:
``pre_encode(key, p, state, sigma, server)`` maps the buffer forward and
``post_encode(state, codec_input, local_decode, server)`` returns its
updated slots, where ``local_decode`` is the exact per-client value the
server will attribute to this payload (scale * signs for the sign codec,
the scattered values for top-k, the quantized levels for qsgd) and
``server`` is the shared server-scope tree (None unless a stage declares
server slots). A stage owning server slots may add an
``update_server(server, g_dec, n_live, n_total)`` hook, run once per round
by the engine's finish step on the DECODED aggregate.

Error-feedback is the canonical instance: ``ef`` adds its residual slot to
the buffer it receives; after the codec runs, the new residual is
``codec_input - local_decode(payload)``. That one rule reproduces
EF-SignSGD and EF-top-k bit-exactly and makes EF work over every codec.

Backend policy lives in core/context.py: ``RoundContext`` carries the
deployment's ``agg_backend`` / ``encode_backend`` / mask guarantee, and
``resolve_backend`` is the one place "auto" becomes pallas-on-TPU /
jnp-elsewhere. ``Pipeline.with_context(ctx)`` rebinds every sign stage —
kernels are dispatched per-stage, not per-class.

The legacy monolithic class names survive as factory functions building the
equivalent pipeline (``EFSignCompressor()`` == ``Pipeline("ef|zsign")``, bit
for bit — pinned in tests/test_pipeline.py); the ``make_compressor(name)``
string entry point was removed in PR 7 after its deprecation cycle — build
a ``Pipeline("<spec>")`` instead (docs/API.md has the migration table).
Fused encode/reduce internals (``fused_sign_encode_jnp``, ``sign_reduce``,
wire-size accounting) are unchanged from the pre-pipeline module — see
wire.py for the accounting notes and kernels/zsign for the TPU paths.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import noise as znoise
from repro.core import wire
from repro.core.context import (AGG_BACKENDS, ENCODE_BACKENDS, RoundContext,
                                resolve_backend)
from repro.core.wire import (WireFormat, pack_flat, pack_signs,
                             unpack_signs, unpack_sum)
# dependency-free substrate module (jax-only): no core <-> fed cycle
from repro.fed import client_state as cstate_lib
from repro.fed.client_state import StateSlot

__all__ = [
    "Pipeline", "SignCodec", "QSGDCodec", "TopKCodec", "DenseCodec",
    "ErrorFeedback", "DPTransform", "ControlVariate", "SigmaSchedule",
    "RoundContext", "StateSlot",
    "Compressor", "ZSignCompressor", "StoSignCompressor", "EFSignCompressor",
    "QSGDCompressor", "TopKCompressor", "DPGaussianCompressor",
    "PackedZSignCompressor", "available", "global_norm",
    "pack_signs", "unpack_signs", "sign_reduce", "fused_sign_encode_jnp",
    "AGG_BACKENDS", "ENCODE_BACKENDS",
]

#: fused-encode tile, in elements. MUST equal kernels/zsign ops.TILE — the
#: jnp fallback reproduces the kernel's per-tile counter stream (asserted in
#: tests without importing the Pallas stack here).
ENCODE_TILE = 8192


def fused_sign_encode_jnp(flat: jax.Array, key, sigma, *, z: int,
                          add_noise: bool = True,
                          chunk_tiles: int = 0) -> jax.Array:
    """Counter-based fused encode, pure jnp — bit-exact vs the Pallas kernel.

    (d,) f32 -> tile-padded bitpacked uint8 (ceil(d/8192)*1024 bytes), the
    identical byte stream ``kernels/zsign ops.zsign_encode_fused`` produces
    for the same key (same global element counters, same per-tile word
    layout, same f32 threshold math — see noise.tile_u01 /
    noise.stochastic_sign_bits).

    ``chunk_tiles == 0`` (default): one elementwise pass. The jaxpr shows a
    (d_pad,) f32 uniform intermediate, but XLA fuses the whole
    threefry -> threshold -> bitpack chain into the uint8 output — compiled
    temp allocation is ~0 bytes where the dense draw allocates 2 x 4d
    (pinned by tests/test_encode_fused.py), and it is the fastest CPU path.

    ``chunk_tiles > 0``: lax.scan over chunks of that many 8192-element
    tiles, bounding even the jaxpr-level live window to
    (chunk_tiles * 8192,) f32 per client — the memory-guarantee-by-
    construction variant (scan carries ~30-80ms of loop overhead per round
    on small CPUs, so it is opt-in rather than the default).
    """
    d = flat.shape[0]
    tile = ENCODE_TILE
    n_tiles = -(-d // tile)
    dpad = n_tiles * tile
    flat = jnp.pad(flat.astype(jnp.float32), (0, dpad - d))
    if not add_noise:
        return pack_flat(flat)
    k0, k1 = znoise.key_words(key)

    def tiles_packed(x_chunk, first_tile, n):
        u = jax.vmap(lambda t: znoise.tile_u01(k0, k1, t * tile, tile))(
            first_tile + jnp.arange(n, dtype=jnp.uint32)).reshape(-1)
        return wire.pack_bool(znoise.stochastic_sign_bits(x_chunk, u, sigma, z))

    if chunk_tiles <= 0 or n_tiles <= chunk_tiles:
        return tiles_packed(flat, jnp.uint32(0), n_tiles)
    n_chunks = -(-n_tiles // chunk_tiles)
    cpad = n_chunks * chunk_tiles * tile - dpad
    x2 = jnp.pad(flat, (0, cpad)).reshape(n_chunks, chunk_tiles * tile)
    starts = jnp.arange(n_chunks, dtype=jnp.uint32) * jnp.uint32(chunk_tiles)
    _, packed = jax.lax.scan(
        lambda _, xs: (None, tiles_packed(xs[0], xs[1], chunk_tiles)),
        None, (x2, starts))
    return packed.reshape(-1)[: dpad // 8]


def sign_reduce(packed: jax.Array, weights: jax.Array,
                backend: str = "auto", *,
                weights_are_mask: bool = False,
                acc: jax.Array | None = None,
                debug: bool = False) -> jax.Array:
    """Weighted sign-reduce over stacked bitpacked payloads.

    (n_clients, n_bytes) u8 + (n_clients,) f32 -> (8*n_bytes,) f32 weighted
    sum of the +/-1 signs, without ever materializing the dense
    (n_clients, d) fp32 sign matrix. Correct for ARBITRARY per-client
    weights on every backend (0/1 participation masks, data-size
    proportional weights, EF mask * scale). ``backend`` resolves through
    :func:`repro.core.context.resolve_backend`:

      auto    Pallas kernel on TPU, wire.unpack_sum elsewhere (the CPU
              LUT-gather path, bit-identical to the kernel)
      pallas  force the fused kernel (interpret mode off-TPU)
      jnp     force wire.unpack_sum
      dense   legacy dense-matrix path (wire.unpack_sum_dense) — oracle and
              benchmark baseline only

    ``weights_are_mask`` is a STATIC caller guarantee that every weight is
    0 or 1 (a participation mask). The membership contract cannot be checked
    on traced values, so it is plumbed from whoever constructs the mask (the
    round engine via ``RoundContext(weights_are_mask=True)``); when set, the
    jnp backend dispatches to the popcount specialization
    ``wire.unpack_sum_mask`` (bit-identical for any 0/1 mask — integer
    sums). Weighted/EF calls keep the LUT path.

    ``acc`` folds a carried partial sum from previous client shards into
    the result — the streaming cohort driver's reduce-as-you-go hook (see
    wire.unpack_sum for the exactness contract). A flat (8*n_bytes,) f32
    ``acc`` continues the plain left fold; a ``wire.SignFoldAcc`` selects
    the shard-partition-INVARIANT structured fold, which buffers sub-block
    client remainders so the result is bit-identical to one concatenated
    call at ANY shard size — that route always runs through
    ``wire.unpack_sum`` (the pending rows are positional state the kernel
    has no inlet for; streaming folds are host/CPU-driven paths). The
    Pallas kernel has no in-kernel init accumulator, so that backend adds a
    flat ``acc`` to the kernel's blocked sum — still integer-exact for 0/1
    masks.

    ``debug`` turns on the dynamic membership assertion of the popcount
    path (``wire.check_mask_membership``; debug-wire mode) — it only fires
    on the ``weights_are_mask`` route, where the contract applies.
    """
    backend = resolve_backend("agg", backend)
    if isinstance(acc, wire.SignFoldAcc):
        return unpack_sum(packed, weights, acc)
    if backend == "pallas":
        from repro.kernels.zsign import ops as K
        out = K.sign_reduce(packed, weights)
        return out if acc is None else acc + out
    if backend == "dense":
        return wire.unpack_sum_dense(packed, weights, acc)
    if weights_are_mask:
        return wire.unpack_sum_mask(packed, weights, acc, debug=debug)
    return unpack_sum(packed, weights, acc)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
            for l in jax.tree_util.tree_leaves(tree)))


def _norm_z(z) -> int:
    """Spec-level z values: "inf" (or any z <= 0 / float inf) -> Z_INF."""
    if isinstance(z, str):
        if z.lower() == "inf":
            return znoise.Z_INF
        raise ValueError(f"z must be an int or 'inf', got {z!r}")
    if isinstance(z, float):
        if math.isinf(z):
            return znoise.Z_INF
        if z != int(z):
            raise ValueError(f"z must be an integer or 'inf', got {z!r} — "
                             f"fractional z has no defined noise law here")
        z = int(z)
    return znoise.Z_INF if z <= znoise.Z_INF else z


# ---------------------------------------------------------------------------
# transform stages
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ErrorFeedback:
    """Per-client error-feedback residual (slot ``"ef"``).

    Pre-codec: the buffer becomes ``p = flat + e``. Post-codec: the new
    residual is ``codec_input - local_decode(payload)`` — exactly what the
    server will NOT see of this client's update. Dead clients keep their
    residual bit-exactly (the engine masks the state update). Composes with
    every codec; with the sign codec the spec parser defaults the codec to
    ``scale="mean_abs"`` so ``ef|zsign`` IS EF-SignSGD.
    """
    spec_name = "ef"
    stateful = True

    def state_spec(self, n_coords: int):
        return (StateSlot("ef", (n_coords,), jnp.float32, "client"),)

    def pre_encode(self, key, p, state, sigma=None, server=None):
        del key, sigma, server
        return p + state["ef"]

    def post_encode(self, state, codec_input, local, server=None):
        del state, server
        return {"ef": codec_input - local}


@dataclasses.dataclass(frozen=True)
class ControlVariate:
    """Compressed SCAFFOLD control variates (SCALLION-style; arXiv:2308.08165).

    Heterogeneous clients drift: client i's local pseudo-gradient estimates
    its OWN data distribution, not the global one — the regime where plain
    sign methods diverge (Stochastic-Sign SGD, arXiv:2002.10940). SCAFFOLD's
    fix is a pair of control variates: a per-client ``c_i`` tracking what
    client i habitually reports, and a shared server variate ``c`` tracking
    the global mean. This stage carries both in the pipeline state substrate
    (slots ``"cv"`` per client, ``"cv_server"`` shared) and keeps the wire
    cost of the downstream codec UNCHANGED — the correction is pre-codec and
    the variate updates are computed from the locally-decoded payload, so
    nothing extra is ever transmitted:

      pre-codec    q_i = p_i - eta * (c_i - c)          (drift correction)
      client       c_i <- c_i + beta * m_i,   m_i = local_decode(payload_i)
      server       c   <- c + beta * (n_live / N) * g_dec        (_finish)

    The server law is EXACT, not approximate: participating clients move
    their variates by beta * m_i, and for every linear-mean codec the
    decoded aggregate is g_dec = (1/n_live) * sum_i m_i, so
    c + (beta * n_live / N) * g_dec == c + (1/N) * sum_i (c_i' - c_i) —
    SCAFFOLD's variate bookkeeping, recovered from the compressed-domain
    accumulator with no dense (n_clients, d) state surface. That exactness
    is WHY this stage refuses nonlinear decode laws (sign ``agg=vote |
    trimmed | median``, top-k ``agg=coord``) at build time: a majority vote
    is not a mean of local decodes, and silently drifting variates are
    worse than a loud error.

    Because the per-client corrections ``c_i - c`` are zero-mean across the
    cohort at the variate fixed point, the server decode law is untouched —
    ``cv|zsign_packed`` ships the same 1 bit/coord payload as
    ``zsign_packed`` and decodes through the same Lemma-1 debias.
    Composes with ``ef`` (the EF residual is ``codec_input - local``, where
    codec_input already carries the cv correction — EF accounts for what
    the codec lost of the CORRECTED buffer) and with ``dp`` upstream.

    ``eta`` scales the correction (SCAFFOLD uses the client step size;
    1.0 applies the raw variate gap), ``beta`` is the variate learning
    rate (1.0 = SCALLION's full replacement-rate tracking).
    """
    eta: float = 1.0
    beta: float = 1.0
    spec_name = "cv"
    stateful = True
    randomized = False
    #: the server-variate update law is exact only for codecs whose
    #: decode_sum is linear in the per-client local decodes — checked at
    #: pipeline build time
    needs_linear_decode = True

    def state_spec(self, n_coords: int):
        return (StateSlot("cv", (n_coords,), jnp.float32, "client"),
                StateSlot("cv_server", (n_coords,), jnp.float32, "server"))

    def pre_encode(self, key, p, state, sigma=None, server=None):
        del key, sigma
        return p - self.eta * (state["cv"] - server["cv_server"])

    def post_encode(self, state, codec_input, local, server=None):
        del codec_input, server
        return {"cv": state["cv"] + self.beta * local}

    def update_server(self, server, g_dec, n_live, n_total):
        """Round-tail server variate update (engine ``_finish``): ``g_dec``
        is the decoded aggregate (possibly pack-padded past n_coords),
        ``n_live`` the traced live weight sum, ``n_total`` the static cohort
        size N."""
        c = server["cv_server"]
        g = g_dec[: c.shape[0]]
        return {"cv_server": c + (self.beta * n_live / n_total) * g}


@dataclasses.dataclass(frozen=True)
class DPTransform:
    """DP clip + Gaussian noise (paper Algorithm 2 client mechanism).

    ``clip`` > 0 clips the flat buffer to that L2 norm; ``noise`` is the
    Gaussian std added afterwards. Instead of ``noise`` you may give a target
    ``eps`` (with ``delta``/``steps``/``q``): the noise multiplier is then
    calibrated through the RDP accountant (core/dp.py) and multiplied by the
    clip norm (the mechanism's sensitivity), so
    ``dp(clip=1.0,eps=2.0,steps=200,q=0.3)`` is a complete client-side DP
    spec.

    When the pipeline's codec is a :class:`SignCodec`, ``Pipeline`` FUSES
    the noise into the codec's sigma at build time: Sign(clip(x) + sigma*xi)
    is sampled straight from its Bernoulli law by the counter-based fused
    encoders, so the dense per-client noise buffer never exists and the wire
    cost stays 1 bit/coord — the paper's "the same noise provides privacy
    and the sign-bias correction", now a structural property of the
    pipeline. Over a dense codec the noise is added here (classic
    DP-FedAvg, 32 bits/coord).
    """
    clip: float = 0.0
    noise: float = 0.0
    eps: float = 0.0
    delta: float = 1e-5
    steps: int = 500
    q: float = 1.0
    #: True iff ``noise`` came from an (eps, delta) calibration — the marker
    #: the Plateau-override refusal keys on (a hand-set noise carries no
    #: privacy promise to protect; the legacy dpgauss law allows overriding
    #: it dynamically)
    calibrated: bool = False
    spec_name = "dp"
    stateful = False

    def __post_init__(self):
        if self.eps > 0.0:
            if self.noise > 0.0:
                raise ValueError("give dp(eps=...) OR dp(noise=...), not "
                                 "both — one target, one mechanism")
            if self.clip <= 0.0:
                raise ValueError("dp(eps=...) needs clip > 0 — the clip norm "
                                 "is the mechanism's sensitivity")
            from repro.core.dp import calibrate_noise
            nm = calibrate_noise(q=self.q, steps=self.steps,
                                 target_eps=self.eps, delta=self.delta,
                                 hi=200.0)
            # eps is consumed into the concrete noise std, so re-running
            # __init__ on this instance (dataclasses.replace) is idempotent
            object.__setattr__(self, "noise", nm * self.clip)
            object.__setattr__(self, "eps", 0.0)
            object.__setattr__(self, "calibrated", True)

    def apply(self, key, flat: jax.Array, sigma=None) -> jax.Array:
        from repro.core.dp import clip_flat
        p = flat
        if self.clip > 0.0:
            p = clip_flat(p, self.clip)
        if (sigma is not None) or self.noise > 0.0:
            sig = self.noise if sigma is None else sigma
            p = p + sig * jax.random.normal(key, p.shape)
        return p

    @property
    def randomized(self) -> bool:
        return self.noise > 0.0


@dataclasses.dataclass(frozen=True)
class SigmaSchedule:
    """Per-layer sigma schedule as a STATIC geometric leaf rescaling.

    One global sigma treats every layer alike, but gradient magnitudes vary
    orders of magnitude across depth — embeddings vs heads. The clean fix
    inside the one-flat-buffer pipeline: scale leaf ``j`` of the ``L``-leaf
    parameter tree by ``m_j = head * (tail / head)^(j / (L - 1))`` BEFORE
    the codec. Because ``Sign(m_j * p + sigma * xi) == Sign(p + (sigma /
    m_j) * xi)``, the wire carries exactly what a per-layer noise scale
    ``sigma / m_j`` would produce — a geometric sigma schedule from the
    first leaf (``sigma / head``) to the last (``sigma / tail``) at zero
    wire cost and zero state. The server decode divides the estimate by the
    same multipliers, restoring each leaf's scale.

    STATELESS and STATIC by design: the multipliers depend only on the tree
    structure (``wire.TreeSpec``), never on data — a data-dependent scale
    could not be inverted server-side without shipping it. The stage
    declares ``needs_tree_spec`` and the engine threads its TreeSpec into
    ``encode(spec=...)`` / ``decode_sum(spec=...)``.

    Composition rules (build-time): must be the FIRST stage (EF residuals
    and dp clipping then live in the scaled domain consistently, round
    over round); refuses ``cv`` outright — the server variate folds the
    UNSCALED decode while client variates would track scaled local decodes,
    so the SCAFFOLD bookkeeping identity breaks.
    """
    head: float = 1.0
    tail: float = 1.0
    spec_name = "sigma_sched"
    stateful = False
    randomized = False
    needs_tree_spec = True

    def __post_init__(self):
        if self.head <= 0.0 or self.tail <= 0.0:
            raise ValueError(f"sigma_sched multipliers must be positive, "
                             f"got head={self.head}, tail={self.tail}")

    def multipliers(self, spec) -> jax.Array:
        """(n_coords,) f32 per-coordinate multiplier, constant per leaf,
        geometric from head (leaf 0) to tail (last leaf)."""
        L = len(spec.shapes)
        if L == 1:
            per_leaf = np.asarray([self.head], np.float32)
        else:
            j = np.arange(L, dtype=np.float64) / (L - 1)
            per_leaf = (self.head * (self.tail / self.head) ** j
                        ).astype(np.float32)
        sizes = np.asarray([int(np.prod(s)) if s else 1
                            for s in spec.shapes])
        return jnp.asarray(np.repeat(per_leaf, sizes))

    def scale(self, p: jax.Array, spec) -> jax.Array:
        m = self.multipliers(spec)
        pad = p.shape[0] - spec.n_coords
        if pad:
            m = jnp.concatenate([m, jnp.ones(pad, p.dtype)])
        return p * m

    def unscale(self, g: jax.Array, spec) -> jax.Array:
        inv = 1.0 / self.multipliers(spec)
        pad = g.shape[0] - spec.n_coords
        if pad:
            inv = jnp.concatenate([inv, jnp.ones(pad, g.dtype)])
        return g * inv


# ---------------------------------------------------------------------------
# wire codec stages
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DenseCodec:
    """Uncompressed fp32 wire (identity / FedAvg baseline)."""
    spec_name = "dense"
    randomized = False

    def wire_format(self) -> WireFormat:
        return WireFormat("float32", 32.0, "dense")

    def encode_with_decode(self, key, p, sigma=None, need_decode=False):
        del key, sigma
        return p, (p if need_decode else None)

    def aggregate(self, payload, mask: jax.Array, n_coords: int,
                  acc: jax.Array | None = None) -> jax.Array:
        del n_coords
        return wire.dense_masked_sum(payload, mask, acc)

    def decode_mean(self, flat_mean, sigma=None):
        del sigma
        return flat_mean


@dataclasses.dataclass(frozen=True)
class SignCodec:
    """The unified stochastic-sign wire codec (paper Algorithm 1, line 11).

    Encodes Sign(p + sigma * xi_z) as bitpacked uint8 (8 coords/byte — the
    TRUE 1-bit uplink) and reduces stacked payloads in the compressed domain
    through :func:`sign_reduce`. One codec covers every sign-family member:

      sigma > 0, sigma_mode="fixed"   z-sign (decode debiases by eta_z*sigma;
                                      Lemma 1). sigma == 0.0 is vanilla
                                      SignSGD with the PRNG statically gated
                                      off on every backend.
      sigma_mode="norm"               sto-sign: per-client sigma_i =
                                      ||p_i||_2 (a traced scalar through the
                                      fused threshold), majority-vote decode.
      scale="mean_abs"                EF-SignSGD wire: the payload carries
                                      ONE fp32 magnitude (mean |p|) next to
                                      the bits; aggregation weights become
                                      mask * scale.

    ``sigma`` is an explicit float field — there is no None-able sigma
    anywhere in the stage config; the engine's dynamic (Plateau) sigma
    arrives as a traced override at encode/decode time. ``encode_backend``
    selects the client path ("auto" | "jnp" | "pallas" | "reference"; see
    context.resolve_backend) — the fused counter-based encoders for
    z in {inf, 1}, or the dense jax.random draw ("reference", and always for
    finite z > 1). ``dense_kernel`` routes the dense-reference path through
    the Pallas ``zsign_compress`` kernel (the ``zsign_packed`` spec);
    ``use_kernel`` enables the fused EF+sign Pallas kernel when composed
    under an ``ef`` transform. ``weights_are_mask`` is the static 0/1-mask
    guarantee plumbed from RoundContext (never set on scale-weighted
    aggregation).

    ``agg`` selects the SERVER aggregation law over the +/-1 votes:

      "mean"        the default weighted sign mean (every path above).
      "vote"        coordinate-wise majority vote (Stochastic-Sign SGD /
                    signSGD-with-majority-vote): sign of the signed count,
                    0 at ties. Byzantine-resilient for f < n/2 flippers.
      "trimmed"     coordinate-wise trimmed mean dropping ``trim_f`` votes
                    at each end (``agg=trimmed(f=2)`` sugar sets trim_f).
      "median"      coordinate-wise median (= deepest trim).

    The robust modes aggregate through the integer (signed_count, n_live)
    VOTE PAIR (``wire.vote_accumulator``): still compressed-domain (no
    (n_clients, d) matrix), still one accumulator across streamed shards,
    still one psum across devices — now int32 of size 2*d_pad. They REQUIRE
    the static ``weights_are_mask`` guarantee (fractional weights have no
    vote-count semantics — refused with an error) and ``scale="none"``
    (mean_abs magnitudes are fractional weights by construction). They
    always run the jnp vote path: the Pallas ``sign_reduce`` kernel
    computes f32 weighted sums, not count pairs, so ``agg_backend`` is
    ignored for robust modes. ``debug_wire`` adds the runtime 0/1-mask
    assertion (checkify) on the popcount/vote paths.
    """
    z: int = 1
    sigma: float = 0.0
    sigma_mode: str = "fixed"        # "fixed" | "norm" (sto-sign)
    scale: str = "none"              # "none" | "mean_abs" (EF-SignSGD wire)
    agg_backend: str = "auto"
    encode_backend: str = "auto"
    encode_chunk_tiles: int = 0      # >0: chunked-scan jnp fallback window
    weights_are_mask: bool = False   # static guarantee: weights are 0/1
    dense_kernel: bool = False       # reference path via Pallas zsign_compress
    use_kernel: bool = False         # fused EF+sign Pallas kernel (under ef)
    agg: str = "mean"                # "mean" | "vote" | "trimmed" | "median"
    trim_f: int = 0                  # votes trimmed per end (agg=trimmed)
    debug_wire: bool = False         # runtime 0/1-mask assertion (checkify)
    spec_name = "zsign"
    randomized = True

    def __post_init__(self):
        object.__setattr__(self, "z", _norm_z(self.z))
        if self.sigma_mode not in ("fixed", "norm"):
            raise ValueError(f"sigma_mode must be 'fixed' or 'norm', "
                             f"got {self.sigma_mode!r}")
        if self.scale not in ("none", "mean_abs"):
            raise ValueError(f"scale must be 'none' or 'mean_abs', "
                             f"got {self.scale!r}")
        # "trimmed(f=2)" spec sugar -> agg="trimmed", trim_f=2
        agg = self.agg
        if isinstance(agg, str) and agg.startswith("trimmed("):
            m = re.fullmatch(r"trimmed\(\s*f\s*=\s*(\d+)\s*\)", agg)
            if not m:
                raise ValueError(f"malformed trimmed agg spec {agg!r}; "
                                 f"expected trimmed(f=<int>)")
            f = int(m.group(1))
            if self.trim_f not in (0, f):
                raise ValueError(f"conflicting trim levels: agg={agg!r} vs "
                                 f"trim_f={self.trim_f}")
            object.__setattr__(self, "agg", "trimmed")
            object.__setattr__(self, "trim_f", f)
        if self.agg not in wire.VOTE_AGG_MODES:
            raise ValueError(f"unknown agg mode {self.agg!r}; expected one "
                             f"of {wire.VOTE_AGG_MODES} (trimmed also as "
                             f"'trimmed(f=<int>)')")
        if self.agg == "trimmed" and self.trim_f < 1:
            raise ValueError("agg=trimmed needs trim_f >= 1 — say "
                             "agg=trimmed(f=2) or trim_f=2; trimmed(f=0) is "
                             "exactly agg=mean")
        if self.agg != "trimmed" and self.trim_f != 0:
            raise ValueError(f"trim_f={self.trim_f} only applies to "
                             f"agg=trimmed, not agg={self.agg!r}")
        if self.agg != "mean" and self.scale != "none":
            raise ValueError(
                f"agg={self.agg!r} requires scale='none': scale="
                f"{self.scale!r} aggregation weights clients by fractional "
                f"magnitudes, which have no integer vote-count semantics "
                f"(robust modes count +/-1 votes under a 0/1 mask)")

    def wire_format(self) -> WireFormat:
        layout = "bitpacked+scale" if self.scale == "mean_abs" else "bitpacked"
        return WireFormat("uint8", 1.0, layout)

    # -- client side --------------------------------------------------------

    def _encode_dense(self, key, flat, sig, add_noise):
        """Dense-draw statistical oracle (and the finite z > 1 path)."""
        if self.dense_kernel:
            from repro.kernels.zsign import ops as K
            if not add_noise:
                # vanilla-SignSGD mode: no noise is drawn (flat doubles as a
                # dummy operand; sigma == 0 makes it a no-op in the kernel)
                return K.zsign_compress(flat, flat, 0.0)
            return K.zsign_compress(
                flat, znoise.sample_z_noise(key, flat.shape, self.z), sig)
        if add_noise:
            flat = flat + sig * znoise.sample_z_noise(key, flat.shape, self.z)
        return pack_flat(flat)

    def _encode_bits(self, key, flat, sig, add_noise):
        backend = resolve_backend("encode", self.encode_backend)
        if backend == "reference" or (add_noise
                                      and not znoise.counter_supported(self.z)):
            return self._encode_dense(key, flat, sig, add_noise)
        if backend == "pallas":
            from repro.kernels.zsign import ops as K
            return K.zsign_encode_fused(flat, key, sig, z=self.z,
                                        add_noise=add_noise)
        return fused_sign_encode_jnp(flat, key, sig, z=self.z,
                                     add_noise=add_noise,
                                     chunk_tiles=self.encode_chunk_tiles)

    def _noise_gate(self, sigma):
        """The ONE place the noise gate is decided: a static sigma of 0.0
        (vanilla SignSGD) disables the draw on every backend; a dynamic
        sigma (possibly traced) always flows through — a runtime 0 degrades
        exactly inside stochastic_sign_bits."""
        if self.sigma_mode == "norm":
            return None, True     # sigma computed from the buffer at encode
        add_noise = (sigma is not None) or self.sigma > 0.0
        return (self.sigma if sigma is None else sigma), add_noise

    def encode_with_decode(self, key, p, sigma=None, need_decode=False):
        """-> (payload, local_decode or None). ``local_decode`` is the exact
        per-client value the server attributes to this payload — what an
        ``ef`` transform upstream subtracts to form its residual."""
        d = p.shape[0]
        sig, add_noise = self._noise_gate(sigma)
        if sig is None:
            sig = jnp.linalg.norm(p)
        if self.scale == "mean_abs":
            s = jnp.mean(jnp.abs(p))
            if not add_noise:
                # EF-SignSGD proper: noise-free signs; residual uses the same
                # p >= 0 convention as the wire payload, so EF accounts
                # exactly for what the server decodes (jnp.sign's 0-at-0
                # would leak +scale per round on zero coords)
                packed = pack_flat(p)
                dec = (s * jnp.where(p >= 0, 1.0, -1.0)
                       if need_decode else None)
            else:
                packed = self._encode_bits(key, p, sig, add_noise)
                dec = (s * unpack_signs(packed)[:d].astype(jnp.float32)
                       if need_decode else None)
            return {"packed": packed, "scale": s}, dec
        packed = self._encode_bits(key, p, sig, add_noise)
        if not need_decode:
            return packed, None
        if self.sigma_mode == "norm" or not add_noise:
            factor = 1.0
        else:
            factor = znoise.eta_z(self.z) * sig
        return packed, factor * unpack_signs(packed)[:d].astype(jnp.float32)

    # -- server side --------------------------------------------------------

    def aggregate(self, payload, mask: jax.Array, n_coords: int,
                  acc: jax.Array | None = None) -> jax.Array:
        del n_coords
        if self.scale == "mean_abs":
            # weights = mask * per-client scale: the fused reduce handles the
            # scale-weighted sum directly in the compressed domain.
            return sign_reduce(payload["packed"], mask * payload["scale"],
                               self.agg_backend, acc=acc)
        if self.agg != "mean":
            if not self.weights_are_mask:
                raise ValueError(
                    f"agg={self.agg!r} requires the static weights_are_mask "
                    f"guarantee (0/1 participation masks): robust sign "
                    f"aggregation counts +/-1 votes, and fractional weights "
                    f"(importance/arrival sampler tiers, data-size weights) "
                    f"have no vote-count semantics. Run under "
                    f"RoundContext(weights_are_mask=True) with a uniform "
                    f"0/1 sampler, or use agg=mean.")
            return wire.vote_accumulator(payload, mask, acc,
                                         debug=self.debug_wire)
        return sign_reduce(payload, mask, self.agg_backend,
                           weights_are_mask=self.weights_are_mask, acc=acc,
                           debug=self.debug_wire)

    def fold_init(self, enc_shape):
        """Structured streaming-fold accumulator, or None when the flat
        zero accumulator is already partition-exact.

        The fp32-WEIGHTED aggregation routes (``scale="mean_abs"`` EF
        wires, and plain mean without the static 0/1-mask guarantee) are
        order-sensitive: a flat fold closes an 8-client LUT block at every
        shard boundary, so off-block shard sizes re-associate the fp32
        sums. For those routes this returns a ``wire.SignFoldAcc`` sized
        from the payload's wire width — the pending-row carry that makes
        the shard fold bit-identical to one concatenated reduce at ANY
        shard partition. Mask-guaranteed and vote routes are integer-exact
        under any association already and keep the flat accumulator
        (None). ``enc_shape`` is the eval_shape of one shard's encoded
        payload stack (dict for the bitpacked+scale wire)."""
        weighted = (self.scale == "mean_abs"
                    or (self.agg == "mean" and not self.weights_are_mask))
        if not weighted:
            return None
        packed = enc_shape["packed"] if isinstance(enc_shape, dict) \
            else enc_shape
        return wire.sign_fold_init(int(packed.shape[-1]))

    def decode_mean(self, flat_mean, sigma=None):
        if self.scale == "mean_abs" or self.sigma_mode == "norm":
            # magnitudes already in the aggregation weights / majority vote
            del sigma
            return flat_mean
        if sigma is None:
            scale = (znoise.eta_z(self.z) * self.sigma
                     if self.sigma > 0.0 else 1.0)
        else:
            scale = znoise.eta_z(self.z) * sigma
        return flat_mean * scale

    def decode_sum(self, enc_sum, n_live, sigma=None):
        """Server estimate from the aggregate output + live count.

        The one server-side decode entry point: for ``agg="mean"`` it is
        ``decode_mean(enc_sum / n_live)`` exactly; for the robust modes
        ``enc_sum`` is the int32 vote pair and the estimate comes from the
        closed forms in ``wire.vote_decode``. Decode laws per mode:

          mean / trimmed   debiased by eta_z * sigma (Lemma 1 — the trimmed
                           mean of the +/-1 votes estimates the same
                           clipped expectation as the mean, so the same
                           linear debias applies; exact only without
                           adversaries, which is the point of trimming).
          vote / median    returned RAW in {-1, 0, +1}: a majority decision
                           is scale-invariant, so there is nothing to
                           debias — the server takes signSGD-style
                           fixed-magnitude steps of server_lr per coord.
        """
        if self.agg == "mean":
            return self.decode_mean(enc_sum / n_live, sigma=sigma)
        est = wire.vote_decode(enc_sum, self.agg, self.trim_f)
        if self.agg == "trimmed":
            return self.decode_mean(est, sigma=sigma)
        return est


@dataclasses.dataclass(frozen=True)
class QSGDCodec:
    """Unbiased stochastic quantizer of Alistarh et al. (paper Definition 2);
    with FedAvg local steps this is FedPAQ/FedCOM. ``s`` quantization levels;
    wire cost derives from s: ceil(log2(2s+1)) bits/coord (+ one fp32 norm,
    amortized)."""
    s: int = 1
    spec_name = "qsgd"
    randomized = True

    def wire_format(self) -> WireFormat:
        return WireFormat("float32",
                          float(math.ceil(math.log2(2 * self.s + 1))),
                          "dense")

    def encode_with_decode(self, key, p, sigma=None, need_decode=False):
        del sigma
        nrm = jnp.linalg.norm(p) + 1e-12
        r = jnp.abs(p) / nrm * self.s
        low = jnp.floor(r)
        up = jax.random.bernoulli(key, jnp.clip(r - low, 0.0, 1.0), p.shape)
        lvl = (low + up.astype(jnp.float32)) / self.s
        q = nrm * jnp.sign(p) * lvl
        return q, (q if need_decode else None)

    def aggregate(self, payload, mask: jax.Array, n_coords: int,
                  acc: jax.Array | None = None) -> jax.Array:
        del n_coords
        return wire.dense_masked_sum(payload, mask, acc)

    def decode_mean(self, flat_mean, sigma=None):
        del sigma
        return flat_mean


@dataclasses.dataclass(frozen=True)
class TopKCodec:
    """Global top-k sparsifier: keep the top ``frac`` of the flat buffer by
    magnitude (GLOBAL across all tensors). COO wire format (values, indices),
    64*frac bits/coord. STATELESS — compose ``ef|topk`` for the classic
    error-corrected variant (the legacy ``topk`` compressor is exactly that
    pipeline).

    Selection runs as a two-stage chunked top-k when d exceeds ``chunk``:
    per-chunk ``lax.top_k`` candidates, then a final top-k over the
    candidate pool — O(d log k / chunk)-ish work instead of one full-buffer
    sort-like pass over all d coordinates, and exactly equivalent to the
    single-stage selection (every global top-k element is in its own chunk's
    top-k; tie-breaking by lowest index is preserved because candidates are
    ordered by (chunk, rank) — verified exhaustively in tests).

    ``chunk=0`` (the default) AUTO-TUNES the chunk size from the buffer at
    trace time: the first stage touches d coordinates and the second
    touches the candidate pool of (d / chunk) * k, so the pool matches the
    chunk at chunk ~ sqrt(d * k) — ``_resolve_chunk`` rounds that up to a
    power of two and clamps it to [4096, 2^20] (below 4096 the per-chunk
    launch overhead dominates; above 2^20 the first stage stops fitting in
    cache). A positive ``chunk`` pins the size explicitly; the selected
    set is identical either way.

    ``agg="coord"`` is the FedDropoutAvg-style COORDINATE-PARTICIPATION
    normalization: because each client reports a different index set, the
    global-n_live mean ("mean") shrinks every coordinate by (reporters /
    n_live). "coord" instead scatter-adds a per-coordinate reporter COUNT
    next to the value sum (a (2, n_coords) accumulator — still additive
    across shards, still one psum across devices) and the decode divides
    each coordinate by ITS OWN reporter count, so a coordinate reported by
    3 of 50 live clients gets the mean of those 3 values, not 3/50 of it.
    Unreported coordinates decode to 0. Composes with ``ef`` (the residual
    is client-local, against the client's OWN scatter — unchanged), but the
    server estimate is no longer linear in the payload stack, so the
    EF-top-k contraction bound applies to the "mean" law only.
    """
    frac: float = 0.01
    chunk: int = 0      # 0 = auto-tune from (d, k); >0 pins the chunk size
    agg: str = "mean"   # "mean" | "coord" (per-coordinate participation)
    spec_name = "topk"
    randomized = False

    def __post_init__(self):
        if self.agg not in ("mean", "coord"):
            raise ValueError(f"topk agg must be 'mean' or 'coord', "
                             f"got {self.agg!r}")
        if self.chunk < 0:
            raise ValueError(f"topk chunk must be 0 (auto) or positive, "
                             f"got {self.chunk}")

    def wire_format(self) -> WireFormat:
        # fp32 value + int32 index per kept coordinate.
        return WireFormat("float32", 64.0 * self.frac, "sparse_coo")

    @staticmethod
    def _resolve_chunk(d: int, k: int) -> int:
        """Auto-tuned chunk size: balance the two stages (first touches d,
        second touches the (d / chunk) * k candidate pool) at
        chunk ~ sqrt(d * k), rounded up to a power of two and clamped to
        [4096, 2^20]. Static per (d, k) — no retrace churn."""
        c = max(1, int(math.sqrt(d * max(1, k))))
        return min(1 << 20, max(4096, 1 << (c - 1).bit_length()))

    def _select(self, score: jax.Array, k: int) -> jax.Array:
        """Indices of the k largest scores (ties -> lowest index first)."""
        d = score.shape[0]
        chunk = self.chunk or self._resolve_chunk(d, k)
        if d <= chunk or k >= chunk:
            _, idx = jax.lax.top_k(score, k)
            return idx
        n_chunks = -(-d // chunk)
        pad = n_chunks * chunk - d
        s = jnp.pad(score, (0, pad), constant_values=-jnp.inf)
        cand_val, cand_idx = jax.lax.top_k(s.reshape(n_chunks, chunk), k)
        base = (jnp.arange(n_chunks, dtype=cand_idx.dtype)[:, None]
                * chunk)
        cand_idx = (cand_idx + base).reshape(-1)
        _, sel = jax.lax.top_k(cand_val.reshape(-1), k)
        return cand_idx[sel]

    def encode_with_decode(self, key, p, sigma=None, need_decode=False):
        del key, sigma
        k = max(1, int(p.shape[0] * self.frac))
        idx = self._select(jnp.abs(p), k)
        vals = p[idx]
        payload = {"values": vals, "indices": idx}
        if not need_decode:
            return payload, None
        # local decode scatters the kept values back; the EF residual
        # p - decode is then exactly p with the selected coords zeroed
        return payload, jnp.zeros_like(p).at[idx].set(vals)

    def aggregate(self, payload, mask: jax.Array, n_coords: int,
                  acc: jax.Array | None = None) -> jax.Array:
        if self.agg == "coord":
            vals = wire.scatter_sum_coo(
                payload["values"], payload["indices"], mask, n_coords,
                None if acc is None else acc[0])
            cnt = wire.scatter_sum_coo(
                jnp.ones_like(payload["values"]), payload["indices"], mask,
                n_coords, None if acc is None else acc[1])
            return jnp.stack([vals, cnt])
        return wire.scatter_sum_coo(payload["values"], payload["indices"],
                                    mask, n_coords, acc)

    def decode_mean(self, flat_mean, sigma=None):
        del sigma
        return flat_mean

    def decode_sum(self, enc_sum, n_live, sigma=None):
        del sigma
        if self.agg == "coord":
            # per-coordinate mean over the clients that REPORTED it; the
            # value row is exactly 0 wherever the count row is 0
            return enc_sum[0] / jnp.maximum(enc_sum[1], 1.0)
        return enc_sum / n_live


# ---------------------------------------------------------------------------
# the pipeline combinator
# ---------------------------------------------------------------------------

_TRANSFORM_SPECS = {"ef": ErrorFeedback, "dp": DPTransform,
                    "cv": ControlVariate, "sigma_sched": SigmaSchedule}


def _sign_spec(**defaults):
    def build(**kw):
        merged = dict(defaults)
        merged.update(kw)
        return SignCodec(**merged)
    return build


_CODEC_SPECS = {
    "zsign": _sign_spec(),
    "zsign_packed": _sign_spec(encode_backend="pallas", dense_kernel=True),
    "stosign": _sign_spec(z=znoise.Z_INF, sigma_mode="norm"),
    "qsgd": QSGDCodec,
    "topk": TopKCodec,
    "dense": DenseCodec,
    "identity": DenseCodec,
}


def _parse_value(v: str):
    low = v.lower()
    if low in ("true", "false"):
        return low == "true"
    for cast in (int, float):
        try:
            return cast(v)
        except ValueError:
            pass
    return v


def _split_args(args: str, tok: str):
    """Split a stage's argument list on TOP-LEVEL commas only, so nested
    call-style values (``agg=trimmed(f=2)``) stay one argument."""
    parts, cur, depth = [], [], 0
    for ch in args:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth < 0:
                raise ValueError(f"unbalanced parentheses in {tok!r}")
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if depth != 0:
        raise ValueError(f"unbalanced parentheses in {tok!r}")
    parts.append("".join(cur))
    return parts


def _parse_stage(tok: str) -> Tuple[str, dict]:
    tok = tok.strip()
    if "(" in tok:
        if not tok.endswith(")"):
            raise ValueError(f"malformed stage spec {tok!r}")
        name, args = tok[:-1].split("(", 1)
        kw = {}
        for part in filter(None,
                           (p.strip() for p in _split_args(args, tok))):
            if "=" not in part:
                raise ValueError(f"stage argument {part!r} in {tok!r} must "
                                 f"be key=value")
            k, v = part.split("=", 1)
            kw[k.strip()] = _parse_value(v.strip())
        return name.strip(), kw
    return tok, {}


def parse_spec(spec: str):
    """Spec string -> (transforms tuple, codec). Grammar:

        spec  := stage ("|" stage)*
        stage := name | name "(" k "=" v ("," k "=" v)* ")"

    Every stage but the last must be a transform (``ef``, ``dp``, ``cv``,
    ``sigma_sched``);
    the last must be a codec (``zsign``, ``zsign_packed``, ``stosign``,
    ``qsgd``, ``topk``, ``dense``/``identity``). Values parse as int, float,
    bool or
    bare string (e.g. ``scale=mean_abs``, ``z=inf``). Convenience defaults:
    an ``ef`` transform in front of a sign codec sets ``scale="mean_abs"``
    unless given explicitly — ``"ef|zsign"`` IS EF-SignSGD.
    """
    toks = [t for t in (p.strip() for p in spec.split("|")) if t]
    if not toks:
        raise ValueError("empty pipeline spec")
    transforms = []
    for tok in toks[:-1]:
        name, kw = _parse_stage(tok)
        if name not in _TRANSFORM_SPECS:
            raise ValueError(
                f"unknown transform stage {name!r} in {spec!r}; transforms: "
                f"{sorted(_TRANSFORM_SPECS)} (codecs must come last)")
        transforms.append(_TRANSFORM_SPECS[name](**kw))
    name, kw = _parse_stage(toks[-1])
    if name not in _CODEC_SPECS:
        raise ValueError(f"unknown codec stage {name!r} in {spec!r}; codecs: "
                         f"{sorted(_CODEC_SPECS)}")
    explicit_scale = "scale" in kw
    codec = _CODEC_SPECS[name](**kw)
    # convenience default: ef over the NOISE-FREE fixed-sigma sign codec is
    # EF-SignSGD, whose wire carries the mean-abs magnitude. Noisy z-sign
    # (sigma > 0, debiased by eta_z * sigma) and sto-sign (norm mode,
    # majority vote) keep their own decode laws under ef. Robust agg modes
    # opt out too: they require scale='none' (mean_abs magnitudes are
    # fractional weights), so "ef|zsign(agg=vote)" is EF over the raw-sign
    # wire with majority-vote decode.
    if (isinstance(codec, SignCodec) and not explicit_scale
            and codec.sigma == 0.0 and codec.sigma_mode == "fixed"
            and codec.agg == "mean"
            and any(isinstance(t, ErrorFeedback) for t in transforms)):
        codec = dataclasses.replace(codec, scale="mean_abs")
    return tuple(transforms), codec


@dataclasses.dataclass(frozen=True)
class Pipeline:
    """Transforms + one wire codec; the engine-facing compressor.

    Build from a spec string (``Pipeline("ef|zsign")``) or from stage
    instances (``Pipeline((ErrorFeedback(),), TopKCodec(frac=0.01))``).
    Frozen and hashable: deployments rebind backend policy with
    :meth:`with_context`, which returns a new pipeline.

    Construction-time rules (idempotent, applied in ``__post_init__``):

      * stateful stages declare named slots (``state_spec``); slot names
        must be unique across stages — a collision is a build-time error.
        The pipeline state the engine replicates per client is the keyed
        dict ``{slot_name: buffer}`` over the client-scope slots;
      * at most one ``ef`` transform (two residuals would double-count the
        compression error);
      * a ``dp`` transform's noise is FUSED into a downstream
        :class:`SignCodec`'s sigma (see :class:`DPTransform`): the codec
        must not carry its own sigma at the same time.
    """
    transforms: Any = ()
    codec: Any = None
    name: str = ""

    def __post_init__(self):
        transforms, codec, name = self.transforms, self.codec, self.name
        if isinstance(transforms, str):
            spec = transforms
            if codec is not None:
                raise ValueError("give either a spec string or stages, "
                                 "not both")
            transforms, codec = parse_spec(spec)
            name = name or spec
        transforms = tuple(transforms)
        if codec is None:
            raise ValueError("pipeline needs a wire codec as its last stage")
        ef_idx = [i for i, t in enumerate(transforms)
                  if isinstance(t, ErrorFeedback)]
        if len(ef_idx) > 1:
            raise ValueError("at most one ef transform per pipeline")
        # dp-noise fusion into the sign codec (see DPTransform docstring)
        if isinstance(codec, SignCodec):
            fused = []
            for t in transforms:
                if isinstance(t, DPTransform) and t.noise > 0.0:
                    if codec.z != 1 or codec.sigma_mode != "fixed":
                        # the dp accountant assumes the GAUSSIAN mechanism;
                        # a z != 1 sign codec samples a different noise law
                        # (z=inf is bounded uniform), which would silently
                        # void the calibrated (eps, delta) guarantee
                        raise ValueError(
                            "dp noise is Gaussian: the sign codec must be "
                            "z=1 with sigma_mode='fixed' to carry it "
                            f"(got z={codec.z}, sigma_mode="
                            f"{codec.sigma_mode!r})")
                    if codec.sigma > 0.0:
                        raise ValueError(
                            "ambiguous noise: both the dp stage and the sign "
                            "codec carry a sigma — set it on one stage only")
                    codec = dataclasses.replace(codec, sigma=t.noise)
                    t = dataclasses.replace(t, noise=0.0, eps=0.0)
                fused.append(t)
            transforms = tuple(fused)
        object.__setattr__(self, "transforms", transforms)
        object.__setattr__(self, "codec", codec)
        object.__setattr__(self, "name", name or self.spec)
        randomized = [i for i, t in enumerate(transforms)
                      if getattr(t, "randomized", False)]
        if getattr(codec, "randomized", False):
            randomized.append(len(transforms))
        object.__setattr__(self, "_n_random", len(randomized))
        stateful = tuple(i for i, t in enumerate(transforms)
                         if getattr(t, "stateful", False))
        object.__setattr__(self, "_stateful_idx", stateful)
        # sigma_sched: at most one, FIRST in the pipeline (so every later
        # stage — EF residuals, dp clip — lives consistently in the scaled
        # domain), never with cv (the server variate folds the unscaled
        # decode — domain mismatch)
        scheds = [i for i, t in enumerate(transforms)
                  if isinstance(t, SigmaSchedule)]
        if len(scheds) > 1:
            raise ValueError("at most one sigma_sched stage per pipeline")
        if scheds:
            if any(isinstance(t, ControlVariate) for t in transforms):
                raise ValueError(
                    "sigma_sched cannot compose with cv: the server "
                    "variate update folds the UNSCALED decoded aggregate "
                    "while client variates would track scaled local "
                    "decodes — the SCAFFOLD bookkeeping identity breaks")
            if scheds[0] != 0:
                raise ValueError(
                    "sigma_sched must be the first stage (e.g. "
                    "'sigma_sched(...)|ef|zsign'): it rescales the raw "
                    "pseudo-gradient, so residuals and clipping must "
                    "happen in the scaled domain")
        object.__setattr__(self, "_needs_spec", any(
            getattr(t, "needs_tree_spec", False) for t in transforms))
        # slot-name collision check (shapes irrelevant at build time) —
        # multi-state pipelines fail loudly here, not deep in the engine
        slots0 = cstate_lib.collect_slots(
            [transforms[i] for i in stateful], 0)
        object.__setattr__(self, "_has_server_state",
                           any(s.scope == "server" for s in slots0))
        # control variates need a decode law linear in the per-client local
        # decodes: the server variate update c+ = c + beta*(n_live/N)*g_dec
        # is exact only when g_dec is the mean of what clients attributed
        # locally. Vote/count laws are not — refuse at build, not at drift.
        linear_needers = [t for t in transforms
                          if getattr(t, "needs_linear_decode", False)]
        if linear_needers:
            bad = None
            if isinstance(codec, SignCodec) and codec.agg != "mean":
                bad = f"the sign codec's agg={codec.agg!r} vote law"
            elif isinstance(codec, TopKCodec) and codec.agg != "mean":
                bad = "topk's agg='coord' per-coordinate count law"
            if bad is not None:
                raise ValueError(
                    f"{linear_needers[0].spec_name} control variates "
                    f"require a server decode LINEAR in the per-client "
                    f"local decodes (the variate update is exact only for "
                    f"mean-law codecs), but {bad} decodes through a "
                    f"nonlinear count — use agg=mean or drop the cv stage")
        # dynamic (Plateau) sigma routes to the sign codec when present,
        # else to the last noise-bearing dp transform (legacy dpgauss law).
        # The noise-free EF-SignSGD wire (scale=mean_abs, sigma == 0) has NO
        # consumer: the legacy EFSignCompressor ignored the engine's dynamic
        # sigma, and silently noising EF payloads under --plateau would be a
        # training-dynamics change (want noisy EF? say zsign(sigma=...)).
        if isinstance(codec, SignCodec):
            consumer = (None if codec.scale == "mean_abs"
                        and codec.sigma == 0.0 else "codec")
        else:
            dps = [i for i, t in enumerate(transforms)
                   if isinstance(t, DPTransform) and t.noise > 0.0]
            consumer = dps[-1] if dps else "codec"
        object.__setattr__(self, "_sigma_stage", consumer)

    # -- construction helpers ------------------------------------------------

    @property
    def spec(self) -> str:
        """Canonical spec string (non-default stage fields spelled out)."""
        def stage_str(s):
            kw = []
            if dataclasses.is_dataclass(s):
                for f in dataclasses.fields(s):
                    v = getattr(s, f.name)
                    if v != f.default:
                        kw.append(f"{f.name}={v}")
            return s.spec_name + (f"({','.join(kw)})" if kw else "")
        return "|".join([stage_str(t) for t in self.transforms]
                        + [stage_str(self.codec)])

    def with_context(self, ctx: RoundContext) -> "Pipeline":
        """Rebind the deployment's backend policy onto every sign stage.

        ``None`` backends in the context keep the stage's own setting (e.g.
        ``zsign_packed`` stays pinned to pallas); explicit values override.
        ``weights_are_mask`` is only applied to pure-mask aggregation —
        scale-weighted (EF) reduces keep the general LUT path.
        ``dynamic_sigma`` is refused on pipelines whose ``dp`` stage was
        (eps, delta)-CALIBRATED: the Plateau controller overriding that
        noise would silently void the guarantee. A hand-set ``dp(noise=..)``
        carries no such promise and keeps the legacy dpgauss law (the
        dynamic sigma overrides it).
        """
        if ctx.dynamic_sigma and any(
                isinstance(t, DPTransform) and t.calibrated
                for t in self.transforms):
            raise ValueError(
                "dynamic (Plateau) sigma cannot run over an eps-calibrated "
                "dp stage: the loss-adaptive override would replace the "
                "privacy-calibrated noise and void the (eps, delta) "
                "guarantee")
        codec = self.codec
        if isinstance(codec, SignCodec):
            kw = {}
            if ctx.agg_backend is not None:
                kw["agg_backend"] = ctx.agg_backend
            if ctx.encode_backend is not None:
                kw["encode_backend"] = ctx.encode_backend
            if ctx.weights_are_mask and codec.scale == "none":
                kw["weights_are_mask"] = True
            if ctx.debug_wire and not codec.debug_wire:
                kw["debug_wire"] = True
            if kw:
                codec = dataclasses.replace(codec, **kw)
        if codec is self.codec:
            return self
        return dataclasses.replace(self, codec=codec)

    def __getattr__(self, item):
        # legacy-compat delegation: codec hyper-parameters (z, sigma, frac,
        # s, _select, ...) read through the pipeline, as they did when each
        # combination was its own class. Dunder lookups never delegate.
        if item.startswith("__"):
            raise AttributeError(item)
        codec = self.__dict__.get("codec")
        if codec is None:
            raise AttributeError(item)
        try:
            return getattr(codec, item)
        except AttributeError:
            raise AttributeError(
                f"{type(self).__name__!s} object has no attribute {item!r}")

    # -- engine-facing compressor interface ---------------------------------

    @property
    def wire_bits_per_coord(self) -> float:
        return self.wire_format().bits_per_coord

    def wire_format(self) -> WireFormat:
        return self.codec.wire_format()

    @property
    def needs_tree_spec(self) -> bool:
        """True when a stage (sigma_sched) needs the engine's wire.TreeSpec
        threaded into ``encode(spec=...)`` / ``decode_sum(spec=...)`` —
        the engine gates the kwarg on this capability flag."""
        return self._needs_spec

    def stacks_group_payloads(self) -> bool:
        """Whether the engine's sequential-group scan should emit the raw
        payload stack (aggregated ONCE over all groups x clients at the end)
        instead of accumulating per-group decoded f32 sums. True exactly
        when the wire layout is compressed — see core/fedavg.py."""
        return self.wire_format().layout != "dense"

    def state_slots(self, n_coords: int):
        """All :class:`StateSlot` declarations of this pipeline's stateful
        stages, in stage order (both client- and server-scope)."""
        return cstate_lib.collect_slots(
            [self.transforms[i] for i in self._stateful_idx], n_coords)

    def init_state(self, n_coords: int):
        """Zero-initialized per-client state: the keyed ``{slot: buffer}``
        dict over client-scope slots, or None for stateless pipelines."""
        return cstate_lib.init_tree(self.state_slots(n_coords), "client")

    def init_server_state(self, n_coords: int):
        """Zero-initialized SHARED server-scope state (control variates):
        the keyed ``{slot: buffer}`` dict over server-scope slots, or None.
        One tree per deployment — the engine replicates it across devices
        and threads it into every client encode (``encode(server=...)``)."""
        return cstate_lib.init_tree(self.state_slots(n_coords), "server")

    def update_server(self, server, g_dec, n_live, n_total):
        """Round-tail update of the shared server-scope state from the
        DECODED aggregate — called once per round by the engine's finish
        step, after ``decode_sum``. Each stateful stage with an
        ``update_server`` hook contributes its slots; stages without one
        keep theirs unchanged. No per-client payloads are consumed here:
        server slots update from the O(d) compressed-domain fold output
        only, so no dense (n_clients, d) surface ever exists."""
        if server is None:
            return None
        new = dict(server)
        for i in self._stateful_idx:
            hook = getattr(self.transforms[i], "update_server", None)
            if hook is not None:
                new.update(hook(server, g_dec, n_live, n_total))
        return new

    def _stage_key(self, key, i: int):
        # a single random stage consumes the raw client key (bit-compat with
        # the legacy monolithic compressors); multiple random stages get
        # fold_in-derived subkeys
        if self._n_random <= 1 or key is None:
            return key
        return jax.random.fold_in(key, i)

    def _ef_kernel_path(self, sigma) -> bool:
        return (len(self.transforms) == 1
                and isinstance(self.transforms[0], ErrorFeedback)
                and isinstance(self.codec, SignCodec)
                and self.codec.use_kernel
                and self.codec.scale == "mean_abs"
                and self.codec.sigma_mode == "fixed"
                and self.codec.sigma == 0.0
                and (sigma is None or self._sigma_stage is None))

    def encode(self, key, flat: jax.Array, state, sigma=None, server=None,
               spec=None):
        """(payload, new_state). ``sigma`` is the engine's dynamic (Plateau)
        override, routed to the pipeline's one sigma consumer. ``server`` is
        the shared server-scope state tree (``init_server_state``) — REQUIRED
        when a stage declares server slots (control variates), unused
        otherwise; the engine passes ``ServerState.comp_server``. ``spec``
        is the flat buffer's wire.TreeSpec — REQUIRED when
        ``needs_tree_spec`` (sigma_sched), unused otherwise."""
        if self._has_server_state and server is None:
            raise ValueError(
                "pipeline declares server-scope state slots (control "
                "variates): encode needs the shared server tree — pass "
                "server=init_server_state(n_coords) (the engine threads "
                "ServerState.comp_server here)")
        if self._needs_spec and spec is None:
            raise ValueError(
                "pipeline declares a tree-structured stage (sigma_sched): "
                "encode needs the flat buffer's wire.TreeSpec — pass "
                "spec=wire.tree_spec(params) (the engine threads its "
                "round TreeSpec here)")
        if self._ef_kernel_path(sigma):
            # one fused VMEM pass: bitpacked payload + residual together
            from repro.kernels.efsign import ops as EK
            res = state["ef"]
            scale = jnp.mean(jnp.abs(flat + res))
            packed, res = EK.ef_sign_encode(flat, res, scale)
            return {"packed": packed, "scale": scale}, {"ef": res}
        p = flat
        for i, t in enumerate(self.transforms):
            sig_i = sigma if self._sigma_stage == i else None
            if getattr(t, "needs_tree_spec", False):
                p = t.scale(p, spec)
            elif getattr(t, "stateful", False):
                p = t.pre_encode(self._stage_key(key, i), p, state,
                                 sigma=sig_i, server=server)
            else:
                p = t.apply(self._stage_key(key, i), p, sigma=sig_i)
        payload, local = self.codec.encode_with_decode(
            self._stage_key(key, len(self.transforms)), p,
            sigma=(sigma if self._sigma_stage == "codec" else None),
            need_decode=bool(self._stateful_idx))
        if not self._stateful_idx:
            return payload, state
        new_state = dict(state)
        for i in self._stateful_idx:
            new_state.update(self.transforms[i].post_encode(state, p, local,
                                                            server=server))
        return payload, new_state

    def aggregate(self, payload, mask: jax.Array, n_coords: int,
                  acc: jax.Array | None = None) -> jax.Array:
        """Masked SUM over the leading client axis of stacked payloads.
        ``n_coords`` is the true (unpadded) coordinate count from the
        engine's TreeSpec — sparse layouts need it to materialize the dense
        sum; others may ignore it and return padded buffers. ``acc`` folds a
        carried partial sum from previous client shards into the result —
        the streaming cohort driver aggregates shard-by-shard through this
        one hook, so the full-cohort payload stack never exists (sign
        families carry O(d/8) of state per fold; dense codecs carry one
        (d,) f32 buffer)."""
        return self.codec.aggregate(payload, mask, n_coords, acc)

    def fold_init(self, enc_shape):
        """Streaming-fold accumulator INITIALIZER for the round driver.

        Returns the codec's structured carry when shard-partition-exact
        folding needs one (SignCodec's fp32-weighted routes return a
        ``wire.SignFoldAcc``), or None when a flat zero accumulator shaped
        by ``aggregate``'s own output is already exact — the driver falls
        back to its eval_shape zeros there. ``enc_shape`` is the
        ``jax.eval_shape`` of one shard's encoded payload stack."""
        init = getattr(self.codec, "fold_init", None)
        return None if init is None else init(enc_shape)

    def fold_finalize(self, acc):
        """Close a streaming-fold accumulator into the plain ``aggregate``
        output the decode path consumes. Structured carries flush their
        pending state (``wire.sign_fold_finalize``); flat accumulators pass
        through unchanged. Multi-device rounds MUST finalize per device
        BEFORE the cross-device psum — pending rows are positional, not
        additive."""
        if isinstance(acc, wire.SignFoldAcc):
            return wire.sign_fold_finalize(acc)
        return acc

    def _unscale(self, g: jax.Array, spec) -> jax.Array:
        # invert tree-structured stages (sigma_sched) in reverse stage order
        if not self._needs_spec:
            return g
        if spec is None:
            raise ValueError(
                "pipeline declares a tree-structured stage (sigma_sched): "
                "decode needs the round's wire.TreeSpec — pass spec=")
        for t in reversed(self.transforms):
            if getattr(t, "needs_tree_spec", False):
                g = t.unscale(g, spec)
        return g

    def decode_mean(self, flat_mean: jax.Array, sigma=None,
                    spec=None) -> jax.Array:
        return self._unscale(self.codec.decode_mean(
            flat_mean,
            sigma=(sigma if self._sigma_stage == "codec" else None)), spec)

    def decode_sum(self, enc_sum: jax.Array, n_live: jax.Array,
                   sigma=None, spec=None) -> jax.Array:
        """Server estimate from the ``aggregate`` output + live count — the
        engine's decode entry point. For codecs whose aggregate is the plain
        masked sum this is ``decode_mean(enc_sum / n_live)`` exactly; codecs
        with a non-mean law (SignCodec robust ``agg=`` modes, TopKCodec
        ``agg=coord``) own the full sum -> estimate mapping through their
        ``decode_sum``. ``spec`` (the round's TreeSpec) is required exactly
        when ``needs_tree_spec`` — sigma_sched inverts its leaf scaling
        here."""
        sig = sigma if self._sigma_stage == "codec" else None
        dec = getattr(self.codec, "decode_sum", None)
        if dec is not None:
            return self._unscale(dec(enc_sum, n_live, sigma=sig), spec)
        return self._unscale(self.codec.decode_mean(enc_sum / n_live,
                                                    sigma=sig), spec)

    def reduce_across_devices(self, acc: jax.Array,
                              axis_name: str) -> jax.Array:
        """Combine per-device partial ``aggregate`` accumulators over a
        shard_map mesh axis. Because every codec's ``aggregate`` is a linear
        fp32 SUM over its client axis — bitpacked sign wires, COO scatters
        and dense einsums alike — the cross-device reduce is one O(d) psum
        of the accumulator (wire.psum_accumulator), NEVER a gather of the
        per-client payload stack. The multi-device streaming driver
        (fedavg.stream_cohort) calls this once per round, after each
        device's shard scan."""
        return wire.psum_accumulator(acc, axis_name)


# ---------------------------------------------------------------------------
# legacy shim: the monolithic compressor names, as pipeline factories
# ---------------------------------------------------------------------------

def Compressor(name: str = "identity") -> Pipeline:
    """Legacy identity compressor -> ``Pipeline(codec=DenseCodec())``."""
    return Pipeline((), DenseCodec(), name=name)


def ZSignCompressor(name: str = "zsign", z: int = 1, sigma: float = 0.01,
                    **kw) -> Pipeline:
    return Pipeline((), SignCodec(z=z, sigma=sigma, **kw), name=name)


def PackedZSignCompressor(name: str = "zsign_packed", z: int = 1,
                          sigma: float = 0.01,
                          encode_backend: str = "pallas", **kw) -> Pipeline:
    return Pipeline((), SignCodec(z=z, sigma=sigma, dense_kernel=True,
                                  encode_backend=encode_backend, **kw),
                    name=name)


def StoSignCompressor(name: str = "stosign", **kw) -> Pipeline:
    return Pipeline((), SignCodec(z=znoise.Z_INF, sigma_mode="norm", **kw),
                    name=name)


def EFSignCompressor(name: str = "efsign", use_kernel: bool = False,
                     **kw) -> Pipeline:
    return Pipeline((ErrorFeedback(),),
                    SignCodec(scale="mean_abs", use_kernel=use_kernel, **kw),
                    name=name)


def QSGDCompressor(name: str = "qsgd", s: int = 1) -> Pipeline:
    return Pipeline((), QSGDCodec(s=s), name=name)


def TopKCompressor(name: str = "topk", frac: float = 0.01,
                   chunk: int = 65536) -> Pipeline:
    return Pipeline((ErrorFeedback(),), TopKCodec(frac=frac, chunk=chunk),
                    name=name)


def DPGaussianCompressor(name: str = "dpgauss",
                         sigma: float = 1.0) -> Pipeline:
    return Pipeline((DPTransform(noise=sigma),), DenseCodec(), name=name)


_REGISTRY = {
    "identity": Compressor,
    "zsign": ZSignCompressor,
    "stosign": StoSignCompressor,
    "efsign": EFSignCompressor,
    "qsgd": QSGDCompressor,
    "topk": TopKCompressor,
    "dpgauss": DPGaussianCompressor,
    "zsign_packed": PackedZSignCompressor,
}


def available() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))
