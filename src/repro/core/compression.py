"""Gradient compressors over the flat wire-buffer codec (core/wire.py).

The paper's contribution (ZSignCompressor) plus every baseline it compares
against: vanilla SignSGD, EF-SignSGD, Sto-SignSGD, QSGD/FedPAQ, top-k, DP
Gaussian, and identity (uncompressed FedAvg). All compressors share one
flat-buffer interface so the federated round engine (core/fedavg.py) treats
them as a plug-in:

    init_state(n_coords)              -> per-client residual buffer or None
    encode(key, flat, state, sigma)   -> (payload, new_state)  # on the client
    aggregate(payload, mask, n_coords)-> (d_pad,) f32 masked SUM  # server
    decode_mean(flat_mean, sigma)     -> (d_pad,) f32 estimate    # server
    wire_format()                     -> WireFormat (dtype, bits/coord, layout)

``flat`` is the pseudo-gradient ((x_{t-1} - x^i_{t,E}) / gamma) flattened
ONCE by the engine into a single fp32 buffer; ``payload`` is what crosses the
network: a bitpacked uint8 buffer for every sign-family compressor (zsign,
zsign_packed, stosign, efsign — 1 bit per coordinate, 32x smaller than fp32),
a COO (values, indices) pair for top-k, dense fp32 otherwise.

``aggregate`` consumes payloads stacked on a leading client axis together
with the (n_clients,) participation mask and returns the masked flat SUM.
All decoders are linear in the per-client encodings, so the server may
aggregate one parallel group per collective or scan-accumulate sums across
sequential client groups — both paths produce identical estimates.

Wire-size accounting: ``wire_bits_per_coord`` (mirrored in ``wire_format()``)
is the logical uplink cost per model coordinate and is derived from the
compressor's own hyper-parameters (e.g. 64*frac for top-k, ceil(log2(2s+1))
for QSGD) — metrics multiply it by the true coordinate count, never by the
padded buffer length.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core import noise as znoise
from repro.core.wire import (WireFormat, pack_flat, pack_signs,
                             unpack_signs, unpack_sum)

__all__ = [
    "Compressor", "ZSignCompressor", "StoSignCompressor", "EFSignCompressor",
    "QSGDCompressor", "TopKCompressor", "DPGaussianCompressor",
    "PackedZSignCompressor", "make_compressor", "available", "global_norm",
    "pack_signs", "unpack_signs",
]


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
            for l in jax.tree_util.tree_leaves(tree)))


# ---------------------------------------------------------------------------
# compressors
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Compressor:
    """Base: identity (uncompressed FedAvg). Dense fp32 wire format."""
    wire_bits_per_coord: float = 32.0
    name: str = "identity"

    def wire_format(self) -> WireFormat:
        return WireFormat("float32", self.wire_bits_per_coord, "dense")

    def init_state(self, n_coords: int) -> Any:
        return None

    def encode(self, key, flat: jax.Array, state, sigma=None) -> Tuple[Any, Any]:
        del key, sigma
        return flat, state

    def decode_mean(self, flat_mean: jax.Array, sigma=None) -> jax.Array:
        del sigma
        return flat_mean

    def aggregate(self, payload, mask: jax.Array, n_coords: int) -> jax.Array:
        """Masked SUM over the leading client axis of stacked payloads.

        ``n_coords`` is the true (unpadded) coordinate count from the
        engine's TreeSpec — sparse layouts need it to materialize the dense
        sum; others may ignore it and return padded buffers.
        Default: dense einsum (one fp32 collective)."""
        del n_coords
        return jnp.einsum("nd,n->d", payload.astype(jnp.float32), mask)


@dataclasses.dataclass(frozen=True)
class ZSignCompressor(Compressor):
    """The paper's stochastic sign operator (Algorithm 1, line 11).

    enc = Sign(flat + sigma * xi_z)  with xi_z ~ p_z  (z<=0 means z = +inf),
    transmitted as a bitpacked uint8 buffer (8 coords/byte — the TRUE 1-bit
    uplink). decode scales by eta_z * sigma — the asymptotically-unbiased
    estimator of Lemma 1. sigma == 0.0 recovers vanilla SignSGD (biased;
    diverges on the paper's counterexample — reproduced in tests).
    """
    z: int = 1
    sigma: float = 0.01
    wire_bits_per_coord: float = 1.0
    name: str = "zsign"

    def wire_format(self) -> WireFormat:
        return WireFormat("uint8", self.wire_bits_per_coord, "bitpacked")

    def _noisy(self, key, flat, sigma):
        add_noise = (sigma is not None) or self.sigma > 0.0
        sig = self.sigma if sigma is None else sigma
        if add_noise:
            flat = flat + sig * znoise.sample_z_noise(key, flat.shape, self.z)
        return flat

    def encode(self, key, flat, state, sigma=None):
        return pack_flat(self._noisy(key, flat, sigma)), state

    def aggregate(self, payload, mask, n_coords):
        del n_coords
        return unpack_sum(payload, mask)

    def decode_mean(self, flat_mean, sigma=None):
        if sigma is None:
            scale = znoise.eta_z(self.z) * self.sigma if self.sigma > 0.0 else 1.0
        else:
            scale = znoise.eta_z(self.z) * sigma
        return flat_mean * scale


@dataclasses.dataclass(frozen=True)
class StoSignCompressor(Compressor):
    """Sto-SignSGD [Safaryan & Richtarik '21] as unified by the paper:
    z = inf with the *input-dependent* noise scale sigma_i = ||flat_i||_2.
    Bitpacked 1-bit wire format."""
    wire_bits_per_coord: float = 1.0
    name: str = "stosign"

    def wire_format(self) -> WireFormat:
        return WireFormat("uint8", self.wire_bits_per_coord, "bitpacked")

    def encode(self, key, flat, state, sigma=None):
        del sigma
        nrm = jnp.linalg.norm(flat)
        xi = jax.random.uniform(key, flat.shape, minval=-1.0, maxval=1.0)
        return pack_flat(flat + nrm * xi), state

    def aggregate(self, payload, mask, n_coords):
        del n_coords
        return unpack_sum(payload, mask)

    def decode_mean(self, flat_mean, sigma=None):
        # majority-vote style: server applies its own stepsize to mean sign.
        del sigma
        return flat_mean


@dataclasses.dataclass(frozen=True)
class EFSignCompressor(Compressor):
    """EF-SignSGD [Karimireddy et al. '19]: scaled sign + per-client residual.

    enc_i = (||p_i||_1 / d) * Sign(p_i),  p_i = flat_i + e_i ;
    e_i <- p_i - enc_i.  The wire payload is the bitpacked sign buffer plus
    ONE fp32 scale (d + 32 bits total, so bits/coord -> 1 as d grows). The
    residual state is a single flat fp32 buffer per client. Stale residuals
    under partial participation are kept exactly (engine masks the state
    update) — matching the paper's related-work discussion of EF's
    partial-participation limitation.
    """
    wire_bits_per_coord: float = 1.0
    name: str = "efsign"
    use_kernel: bool = False   # fused Pallas EF step (kernels/efsign)

    def wire_format(self) -> WireFormat:
        return WireFormat("uint8", self.wire_bits_per_coord, "bitpacked+scale")

    def init_state(self, n_coords: int):
        return jnp.zeros((n_coords,), jnp.float32)

    def encode(self, key, flat, state, sigma=None):
        del key, sigma
        p = flat + state
        scale = jnp.mean(jnp.abs(p))
        if self.use_kernel:
            # one fused VMEM pass: bitpacked payload + residual together
            from repro.kernels.efsign import ops as EK
            packed, res = EK.ef_sign_encode(flat, state, scale)
        else:
            # residual uses the same p >= 0 sign convention as the wire
            # payload, so EF accounts exactly for what the server decodes
            # (jnp.sign's 0-at-0 would leak +scale per round on zero coords)
            packed = pack_flat(p)
            res = p - scale * jnp.where(p >= 0, 1.0, -1.0)
        return {"packed": packed, "scale": scale}, res

    def aggregate(self, payload, mask, n_coords):
        del n_coords
        return unpack_sum(payload["packed"], mask * payload["scale"])

    def decode_mean(self, flat_mean, sigma=None):
        del sigma
        return flat_mean


@dataclasses.dataclass(frozen=True)
class QSGDCompressor(Compressor):
    """Unbiased stochastic quantizer of Alistarh et al. (paper Definition 2);
    with FedAvg local steps this is FedPAQ/FedCOM. ``s`` quantization levels;
    wire cost derives from s: ceil(log2(2s+1)) bits/coord (+ one fp32 norm,
    amortized)."""
    s: int = 1
    wire_bits_per_coord: float = 2.0
    name: str = "qsgd"

    def __post_init__(self):
        object.__setattr__(self, "wire_bits_per_coord",
                           float(math.ceil(math.log2(2 * self.s + 1))))

    def encode(self, key, flat, state, sigma=None):
        del sigma
        nrm = jnp.linalg.norm(flat) + 1e-12
        r = jnp.abs(flat) / nrm * self.s
        low = jnp.floor(r)
        up = jax.random.bernoulli(key, jnp.clip(r - low, 0.0, 1.0), flat.shape)
        lvl = (low + up.astype(jnp.float32)) / self.s
        return nrm * jnp.sign(flat) * lvl, state

    def decode_mean(self, flat_mean, sigma=None):
        del sigma
        return flat_mean


@dataclasses.dataclass(frozen=True)
class TopKCompressor(Compressor):
    """Beyond-paper sparsifier baseline: keep the top-k fraction of the flat
    buffer by magnitude (GLOBAL top-k across all tensors) with per-client
    error feedback. COO wire format: (values, indices), 64*frac bits/coord.
    """
    frac: float = 0.01
    wire_bits_per_coord: float = 0.64  # overwritten in __post_init__
    name: str = "topk"

    def __post_init__(self):
        # fp32 value + int32 index per kept coordinate.
        object.__setattr__(self, "wire_bits_per_coord", 64.0 * self.frac)

    def wire_format(self) -> WireFormat:
        return WireFormat("float32", self.wire_bits_per_coord, "sparse_coo")

    def init_state(self, n_coords: int):
        return jnp.zeros((n_coords,), jnp.float32)

    def encode(self, key, flat, state, sigma=None):
        del key, sigma
        p = flat + state
        k = max(1, int(p.shape[0] * self.frac))
        _, idx = jax.lax.top_k(jnp.abs(p), k)
        return {"values": p[idx], "indices": idx}, p.at[idx].set(0.0)

    def aggregate(self, payload, mask, n_coords):
        # scatter-add each client's COO payload into the dense flat space.
        vals = (payload["values"] * mask[:, None]).reshape(-1)
        idx = payload["indices"].reshape(-1)
        return jnp.zeros((n_coords,), jnp.float32).at[idx].add(vals)

    def decode_mean(self, flat_mean, sigma=None):
        del sigma
        return flat_mean


@dataclasses.dataclass(frozen=True)
class DPGaussianCompressor(Compressor):
    """Uncompressed DP-FedAvg mechanism: transmit flat + N(0, sigma^2 I)
    (clipping happens in the round engine via cfg.dp_clip). 32 bits/coord."""
    sigma: float = 1.0
    wire_bits_per_coord: float = 32.0
    name: str = "dpgauss"

    def encode(self, key, flat, state, sigma=None):
        sig = self.sigma if sigma is None else sigma
        return flat + sig * jax.random.normal(key, flat.shape), state


@dataclasses.dataclass(frozen=True)
class PackedZSignCompressor(ZSignCompressor):
    """z-sign through the Pallas TPU kernels (kernels/zsign): encode fuses
    noise-add + sign + 8:1 bitpack into one VMEM pass; the server unpack+sum
    runs the companion kernel per client row. Bit-for-bit identical wire
    bytes to the pure-jnp ``pack_flat`` path (verified in tests), just fused.
    Payload is uint8 of ceil(d/8192)*1024 bytes (kernel tile padding; the
    logical cost stays 1 bit/coord — see wire.py accounting notes).
    """
    name: str = "zsign_packed"

    def encode(self, key, flat, state, sigma=None):
        from repro.kernels.zsign import ops as K
        sig = self.sigma if sigma is None else sigma
        noise = znoise.sample_z_noise(key, flat.shape, self.z)
        return K.zsign_compress(flat, noise, sig), state

    def aggregate(self, payload, mask, n_coords):
        from repro.kernels.zsign import ops as K
        del n_coords
        n, nb = payload.shape
        signs = jax.vmap(
            lambda row: K.zsign_decompress_sum(row[None], nb * 8))(payload)
        return jnp.einsum("nd,n->d", signs, mask)


_REGISTRY = {
    "identity": Compressor,
    "zsign": ZSignCompressor,
    "stosign": StoSignCompressor,
    "efsign": EFSignCompressor,
    "qsgd": QSGDCompressor,
    "topk": TopKCompressor,
    "dpgauss": DPGaussianCompressor,
    "zsign_packed": PackedZSignCompressor,
}


def available() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def make_compressor(name: str, **kw) -> Compressor:
    return _REGISTRY[name](name=name, **kw)
