"""Gradient compressors.

The paper's contribution (ZSignCompressor) plus every baseline it compares
against: vanilla SignSGD, EF-SignSGD, Sto-SignSGD, QSGD/FedPAQ, and identity
(uncompressed FedAvg). All compressors share one interface so the federated
round engine (core/fedavg.py) treats them as a plug-in:

    init_state(params)            -> per-client compressor state (pytree or None)
    encode(key, g, state)         -> (enc, new_state)      # runs on the client
    decode_mean(enc_mean_or_sum)  -> pseudo-gradient estimate  # on the server
    wire_bits_per_coord           -> float, for the communication accounting

``g`` is the pseudo-gradient pytree ((x_{t-1} - x^i_{t,E}) / gamma).  Encoded
leaves are int8 sign tensors (or bitpacked uint8 when ``bitpack=True``), so the
cross-client collective moves 8x/32x fewer bytes than fp32.

Decoders are linear in the per-client encodings, so the server may aggregate
either ``mean_i enc_i`` (one int8 collective) or a scan-accumulated sum for
sequential client groups — both paths produce identical estimates.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import noise as znoise


def _tree_keys(key: jax.Array, tree):
    """One PRNG key per leaf."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    return jax.tree_util.tree_unflatten(treedef, list(keys))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
            for l in jax.tree_util.tree_leaves(tree)))


# ---------------------------------------------------------------------------
# bit packing (pure-jnp reference path; the Pallas kernel in kernels/zsign is
# the fused fast path and is verified against this in tests)
# ---------------------------------------------------------------------------

def pack_signs(signs_i8: jax.Array) -> jax.Array:
    """int8 {-1,+1} (flat, len % 8 == 0) -> uint8 bitfield of len/8."""
    bits = (signs_i8 > 0).astype(jnp.uint8).reshape(-1, 8)
    weights = (jnp.uint8(1) << jnp.arange(8, dtype=jnp.uint8))
    return jnp.sum(bits * weights, axis=-1).astype(jnp.uint8)


def unpack_signs(packed: jax.Array) -> jax.Array:
    """uint8 bitfield -> int8 {-1,+1} of len*8."""
    weights = (jnp.uint8(1) << jnp.arange(8, dtype=jnp.uint8))
    bits = (packed[:, None] & weights) > 0
    return jnp.where(bits, jnp.int8(1), jnp.int8(-1)).reshape(-1)


def _pad_to(x: jax.Array, mult: int) -> jax.Array:
    r = (-x.shape[0]) % mult
    return jnp.pad(x, (0, r)) if r else x


# ---------------------------------------------------------------------------
# compressors
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Compressor:
    """Base: identity (uncompressed FedAvg)."""
    wire_bits_per_coord: float = 32.0
    name: str = "identity"

    def init_state(self, params) -> Any:
        return None

    def encode(self, key, g, state, sigma=None) -> Tuple[Any, Any]:
        del key, sigma
        return g, state

    def decode_mean(self, enc_mean, sigma=None):
        del sigma
        return enc_mean

    def aggregate(self, enc, mask):
        """Masked SUM over the leading client axis of stacked encodings.
        Default: dense einsum (the int8/fp collective path)."""
        return jax.tree.map(
            lambda e: jnp.einsum("n...,n->...", e.astype(jnp.float32), mask),
            enc)


@dataclasses.dataclass(frozen=True)
class ZSignCompressor(Compressor):
    """The paper's stochastic sign operator (Algorithm 1, line 11).

    enc = Sign(g + sigma * xi_z)  with xi_z ~ p_z  (z<=0 means z = +inf).
    decode scales by eta_z * sigma — the asymptotically-unbiased estimator of
    Lemma 1.  sigma == 0.0 recovers vanilla SignSGD (biased; diverges on the
    paper's counterexample — reproduced in tests).
    """
    z: int = 1
    sigma: float = 0.01
    wire_bits_per_coord: float = 1.0
    name: str = "zsign"

    def encode(self, key, g, state, sigma=None):
        keys = _tree_keys(key, g)
        add_noise = (sigma is not None) or self.sigma > 0.0
        sig = self.sigma if sigma is None else sigma

        def enc_leaf(k, x):
            x = x.astype(jnp.float32)
            if add_noise:
                x = x + sig * znoise.sample_z_noise(k, x.shape, self.z)
            return jnp.where(x >= 0, jnp.int8(1), jnp.int8(-1))

        return jax.tree.map(enc_leaf, keys, g), state

    def decode_mean(self, enc_mean, sigma=None):
        if sigma is None:
            scale = znoise.eta_z(self.z) * self.sigma if self.sigma > 0.0 else 1.0
        else:
            scale = znoise.eta_z(self.z) * sigma
        return jax.tree.map(lambda s: s.astype(jnp.float32) * scale, enc_mean)


@dataclasses.dataclass(frozen=True)
class StoSignCompressor(Compressor):
    """Sto-SignSGD [Safaryan & Richtarik '21] as unified by the paper:
    z = inf with the *input-dependent* noise scale sigma_i = ||g_i||_2."""
    wire_bits_per_coord: float = 1.0
    name: str = "stosign"

    def encode(self, key, g, state, sigma=None):
        sigma = global_norm(g)
        keys = _tree_keys(key, g)

        def enc_leaf(k, x):
            xi = jax.random.uniform(k, x.shape, minval=-1.0, maxval=1.0)
            return jnp.where(x.astype(jnp.float32) + sigma * xi >= 0,
                             jnp.int8(1), jnp.int8(-1))

        return jax.tree.map(enc_leaf, keys, g), state

    def decode_mean(self, enc_mean, sigma=None):
        # majority-vote style: server applies its own stepsize to mean sign.
        del sigma
        return jax.tree.map(lambda s: s.astype(jnp.float32), enc_mean)


@dataclasses.dataclass(frozen=True)
class EFSignCompressor(Compressor):
    """EF-SignSGD [Karimireddy et al. '19]: scaled sign + per-client residual.

    enc_i = (||p_i||_1 / d) * Sign(p_i),  p_i = g_i + e_i ;
    e_i <- p_i - enc_i.  The scale is transmitted as one fp32 per tensor
    (d + 32 bits).  Cannot handle partial participation (residuals go stale) —
    documented limitation, matching the paper's related-work discussion.
    """
    wire_bits_per_coord: float = 1.0
    name: str = "efsign"

    def init_state(self, params):
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    use_kernel: bool = False   # fused Pallas EF step (kernels/efsign)

    def encode(self, key, g, state, sigma=None):
        del key

        def enc_leaf(x, e):
            p = x.astype(jnp.float32) + e
            scale = jnp.mean(jnp.abs(p))
            if self.use_kernel:
                from repro.kernels.efsign import ops as EK
                return EK.ef_sign_update(x.astype(jnp.float32), e, scale)
            q = scale * jnp.sign(p)
            return q, p - q

        enc_and_res = jax.tree.map(enc_leaf, g, state)
        enc = jax.tree.map(lambda t: t[0], enc_and_res,
                           is_leaf=lambda t: isinstance(t, tuple))
        res = jax.tree.map(lambda t: t[1], enc_and_res,
                           is_leaf=lambda t: isinstance(t, tuple))
        return enc, res

    def decode_mean(self, enc_mean, sigma=None):
        del sigma
        return enc_mean


@dataclasses.dataclass(frozen=True)
class QSGDCompressor(Compressor):
    """Unbiased stochastic quantizer of Alistarh et al. (paper Definition 2);
    with FedAvg local steps this is FedPAQ/FedCOM.  ``s`` quantization levels.
    """
    s: int = 1
    wire_bits_per_coord: float = 2.0  # ~log2(2s+1) + norm overhead
    name: str = "qsgd"

    def encode(self, key, g, state, sigma=None):
        keys = _tree_keys(key, g)

        def enc_leaf(k, x):
            x = x.astype(jnp.float32)
            nrm = jnp.linalg.norm(x.reshape(-1)) + 1e-12
            r = jnp.abs(x) / nrm * self.s
            low = jnp.floor(r)
            up = jax.random.bernoulli(k, jnp.clip(r - low, 0.0, 1.0), x.shape)
            lvl = (low + up.astype(jnp.float32)) / self.s
            return nrm * jnp.sign(x) * lvl

        return jax.tree.map(enc_leaf, keys, g), state

    def decode_mean(self, enc_mean, sigma=None):
        del sigma
        return enc_mean


@dataclasses.dataclass(frozen=True)
class TopKCompressor(Compressor):
    """Beyond-paper sparsifier baseline: keep top-k fraction by magnitude with
    per-client error feedback."""
    frac: float = 0.01
    wire_bits_per_coord: float = 32.0 * 2 * 0.01  # value+index on kept coords
    name: str = "topk"

    def init_state(self, params):
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def encode(self, key, g, state, sigma=None):
        del key

        def enc_leaf(x, e):
            p = (x.astype(jnp.float32) + e).reshape(-1)
            k = max(1, int(p.size * self.frac))
            thresh = jax.lax.top_k(jnp.abs(p), k)[0][-1]
            q = jnp.where(jnp.abs(p) >= thresh, p, 0.0).reshape(x.shape)
            return q, p.reshape(x.shape) - q

        enc_and_res = jax.tree.map(enc_leaf, g, state)
        enc = jax.tree.map(lambda t: t[0], enc_and_res,
                           is_leaf=lambda t: isinstance(t, tuple))
        res = jax.tree.map(lambda t: t[1], enc_and_res,
                           is_leaf=lambda t: isinstance(t, tuple))
        return enc, res

    def decode_mean(self, enc_mean, sigma=None):
        del sigma
        return enc_mean


@dataclasses.dataclass(frozen=True)
class DPGaussianCompressor(Compressor):
    """Uncompressed DP-FedAvg mechanism: transmit g + N(0, sigma^2 I)
    (clipping happens in the round engine via cfg.dp_clip). 32 bits/coord."""
    sigma: float = 1.0
    wire_bits_per_coord: float = 32.0
    name: str = "dpgauss"

    def encode(self, key, g, state, sigma=None):
        sig = self.sigma if sigma is None else sigma
        keys = _tree_keys(key, g)
        enc = jax.tree.map(
            lambda k, x: x.astype(jnp.float32)
            + sig * jax.random.normal(k, x.shape), keys, g)
        return enc, state

    def decode_mean(self, enc_mean, sigma=None):
        del sigma
        return enc_mean


@dataclasses.dataclass(frozen=True)
class PackedZSignCompressor(ZSignCompressor):
    """z-sign with the TRUE 1-bit wire format, via the Pallas TPU kernels
    (kernels/zsign): encode fuses noise+sign+bitpack to uint8 (8 coords per
    byte — what actually crosses the network); the server aggregation
    unpacks + sums with the companion kernel. Encoded leaves are
    {"packed": uint8[ceil(n/8)]} per parameter; decoders are linear, so the
    engine's group-sum path is unchanged.
    """
    name: str = "zsign_packed"

    def encode(self, key, g, state, sigma=None):
        from repro.kernels.zsign import ops as K
        keys = _tree_keys(key, g)
        sig = self.sigma if sigma is None else sigma

        def enc_leaf(k, x):
            noise = znoise.sample_z_noise(k, x.shape, self.z)
            return K.zsign_compress(x.astype(jnp.float32), noise, sig)

        return jax.tree.map(enc_leaf, keys, g), state

    def aggregate(self, enc, mask):
        from repro.kernels.zsign import ops as K

        def agg_leaf(e):
            # e: (n_clients, n_bytes) uint8. Unpack+sum via the kernel for
            # the full-participation fast path; masked clients handled by
            # zeroing their +/-1 contribution (unpack then weight).
            n, nb = e.shape
            signs = jax.vmap(
                lambda row: K.zsign_decompress_sum(row[None], nb * 8))(e)
            return jnp.einsum("nd,n->d", signs, mask)

        return jax.tree.map(agg_leaf, enc)

    def decode_mean(self, enc_mean, sigma=None):
        # enc_mean leaves are flat (padded) sign-means; reshaping back to the
        # parameter shapes happens in unflatten_like.
        return super().decode_mean(enc_mean, sigma)

    @staticmethod
    def unflatten_like(flat_tree, params):
        return jax.tree.map(
            lambda f, p: f[: p.size].reshape(p.shape), flat_tree, params)


_REGISTRY = {
    "identity": Compressor,
    "zsign": ZSignCompressor,
    "stosign": StoSignCompressor,
    "efsign": EFSignCompressor,
    "qsgd": QSGDCompressor,
    "topk": TopKCompressor,
    "dpgauss": DPGaussianCompressor,
    "zsign_packed": PackedZSignCompressor,
}


def make_compressor(name: str, **kw) -> Compressor:
    return _REGISTRY[name](name=name, **kw)
