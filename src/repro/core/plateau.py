"""Plateau criterion for adaptive noise scale (paper §4.4).

Start with sigma_init; whenever the objective has not improved for ``kappa``
communication rounds, set sigma <- beta * sigma (beta in [1.5, 2]); stop
growing once sigma >= sigma_bound.  Runs host-side between jitted rounds —
sigma enters the round step as a dynamic scalar, so no recompiles.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass
class PlateauController:
    sigma_init: float
    sigma_bound: float
    kappa: int
    beta: float = 1.5
    rel_improve: float = 1e-4   # minimum relative improvement that counts

    sigma: float = dataclasses.field(init=False)
    best: float = dataclasses.field(init=False, default=math.inf)
    stale: int = dataclasses.field(init=False, default=0)
    history: list = dataclasses.field(init=False, default_factory=list)

    def __post_init__(self):
        if not (self.sigma_bound >= self.sigma_init > 0):
            raise ValueError("require sigma_bound >= sigma_init > 0")
        self.sigma = self.sigma_init

    def update(self, loss: float) -> float:
        """Feed the round loss; returns the sigma for the *next* round."""
        loss = float(loss)
        if loss < self.best * (1.0 - self.rel_improve) or not math.isfinite(self.best):
            self.best = loss
            self.stale = 0
        else:
            self.stale += 1
            if self.stale >= self.kappa and self.sigma < self.sigma_bound:
                self.sigma = min(self.sigma * self.beta, self.sigma_bound)
                self.stale = 0
                self.best = loss  # re-anchor after a scale change
        self.history.append(self.sigma)
        return self.sigma
