"""z-SignFedAvg round engine (paper Algorithm 1, plus every baseline).

One *round step* is a single jitted function:

    broadcast server params -> vmap over parallel clients:
        scan over E local SGD steps -> pseudo-gradient (x0 - xE)/gamma
        -> flatten ONCE to a 1-D fp32 wire buffer (core/wire.TreeSpec)
        -> compressor.encode  (the bitpacked 1-bit uplink payload)
    -> participation-masked flat aggregation over the client axis
       (uint8 collective + fused weighted sign-reduce == the compressed
       all-reduce; sign families never re-inflate the dense sign matrix;
       robust ``agg=vote|trimmed|median`` modes carry the int32 vote pair)
    -> compressor.decode_sum -> unflatten ONCE -> server optimizer update.

RoundContext.adversary threads a wire-level fault-injection policy
(fed/adversary.py) through every cohort plan: mid-round dropout is applied
to the slot mask at the top of the round; payload attacks (sign-flip, byte
corruption, collusion) hit each shard's encoded uint8 stack inside
``group_encode``, selected by GLOBAL client index + round counter so the
attack is bit-identical under vmap, stream(shard=K) and stream(devices=D).

The engine never touches per-leaf encodings: every compression Pipeline
(core/compression.py) speaks the flat wire-buffer codec of core/wire.py, so
there are no compressor-specific branches here — sign families ship
bitpacked uint8, top-k ships COO pairs, identity ships fp32, all through the
same four calls. Deployment policy (backend selection, mask guarantees,
dynamic sigma, legacy paths, cohort execution) arrives as ONE typed value —
the RoundContext of core/context.py — applied to the pipeline at build time.

The engine is split in two halves:

ROUND MATH (``_build_round_math``) — per-shard client compute: the local-SGD
scan, the fused encode, and the participation-masked state update for one
slice of clients, vmapped over that slice's leading axis. Pure in the shard:
it never knows how many shards exist or how they are scheduled.

ROUND DRIVER (``build_round_step``) — shard scheduling and slicing: derives
per-client PRNG keys by GLOBAL client index (noise.client_keys — a counter
derivation, so results are invariant to how the cohort is partitioned),
slices batch/mask/state per shard, and aggregates. ``RoundContext.cohort``
picks the walk:

  ``vmap``    one vmap over all ``n_clients`` parallel clients; sequential
              client *groups* are an outer ``lax.scan``. For compressed wire
              layouts the scan emits the raw payload stack as its OUTPUT and
              the server runs ONE ``aggregate`` over the (client_groups *
              n_clients, n_bytes) stack; dense fp32 layouts accumulate the
              decoded group sums in the scan carry (the choice is the
              compressor's ``stacks_group_payloads()``).
  ``stream``  the massive-cohort executor: the flat cohort of
              ``client_groups * n_clients`` clients is resharded into
              ``shard``-client slices and scanned, folding each shard's
              payload stack into ONE running wire accumulator via
              ``Pipeline.aggregate(..., acc=...)`` (reduce-as-you-go — a
              full-cohort payload stack never exists). Peak memory is O(d)
              model + O(shard * E * batch) data + O(shard * d/8) wire for
              sign families (one (d,) f32 carry for dense codecs), for ANY
              cohort size. Bit-identical to the vmap path at ANY shard
              size: 0/1-mask sign sums are integer-exact, and fp32-weighted
              (EF) aggregation streams through a ``wire.SignFoldAcc``
              carry (``Pipeline.fold_init``) that preserves the full
              call's 8-client block order; see wire.unpack_sum.

              ``stream(devices=D)`` adds the cross-DEVICE axis: the shard
              sequence is partitioned into contiguous per-device slices
              over a 1-D ``clients`` mesh (``shard_map``); every device
              runs the same shard scan on its slice, folding into its own
              local wire accumulator, and the accumulators meet in ONE
              ``lax.psum`` (wire.psum_accumulator) before decode — the
              cross-device reduce stays in the compressed-sum domain, so
              per-device interconnect traffic is O(d) fp32 regardless of
              cohort size (never a payload stack, never per-client data).
              Model params are replicated; batch/mask/EF-state shards are
              device-local; the per-client EF residuals come back sharded
              along the cohort axis. Counter-based client keys make the
              bits invariant to device placement, so D in {1..} produces
              bit-identical rounds for 0/1 masks at any shard size.

              ``stream(feed=host)`` swaps the device-resident shard tensor
              for a host-side double-buffered feeder (``iter_shards`` +
              async ``jax.device_put`` of shard t+1 while shard t
              computes): only ONE shard of batch/mask/state lives on
              device at a time, for cohorts whose round tensors exceed
              device memory. The returned round step is a Python loop —
              do not wrap it in jax.jit.
  ``auto``    stream iff ``total_clients * n_coords`` reaches
              context.STREAM_AUTO_MIN_ELEMS — small rounds keep the vmap
              path (measured on XLA CPU the shard lax.scan costs only
              ~0.1-0.2 ms/shard of loop overhead and the plans are within
              ~5% for unpacked wires; see the constant's docstring for the
              numbers), huge cohorts get the O(wire) memory contract. A
              bare ``stream`` gates the same way; ``stream(shard=K)`` /
              ``devices=`` / ``feed=host`` force.

Per-client compressor state (EF / top-k residuals) is a flat fp32 buffer of
shape (client_groups, n_clients, n_coords); dead clients keep their previous
residual bit-exactly (the state update is participation-masked). When the
cohort does not divide the shard size, the last shard is padded with
wrapped-around batch rows under a zero participation mask — padded slots
contribute exactly nothing and their state rows are discarded.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import noise as znoise
from repro.core import wire
from repro.core.context import (COHORT_DEVICES_AUTO, STREAM_AUTO_MIN_ELEMS,
                                STREAM_DEFAULT_SHARD, STREAM_SHARD_AUTO,
                                STREAM_SHARD_BUDGET_BYTES, STREAM_SHARD_MAX,
                                STREAM_SHARD_MIN, CohortPolicy, RoundContext,
                                RoundModePolicy)
from repro.core.dp import clip_flat
from repro.optim.optimizers import Optimizer, make_optimizer


@dataclasses.dataclass(frozen=True)
class FedConfig:
    n_clients: int = 8            # parallel clients (vmapped / mesh-sharded)
    client_groups: int = 1        # sequential groups; total clients = n*groups
    local_steps: int = 1          # E
    client_lr: float = 0.01       # gamma
    server_lr: float = 1.0        # eta (decode already applies eta_z * sigma)
    server_opt: str = "sgd"       # sgd | momentum | adam
    server_opt_kw: tuple = ()     # e.g. (("momentum", 0.9),)
    dp_clip: float = 0.0          # >0 enables DP-SignFedAvg clipping (Alg. 2)


class ServerState(NamedTuple):
    params: Any
    opt_state: Any
    #: stacked per-client state tree {slot: (G, N, ...)} or None
    comp_state: Any
    rng: jax.Array
    round: jax.Array      # int32 scalar
    sigma: jax.Array      # dynamic noise scale (Plateau criterion)
    #: SHARED server-scope pipeline state ({slot: (n_coords,)} control
    #: variates) or None. Defaulted LAST field: existing keyword
    #: constructions and old checkpoints stay valid.
    comp_server: Any = None


class RoundMetrics(NamedTuple):
    loss: jax.Array
    grad_est_norm: jax.Array
    participation: jax.Array
    uplink_bits: jax.Array
    #: clients per stream shard this round (0 on the vmap plan) — recorded so
    #: benchmark rows stay self-describing when the shard size is auto-tuned.
    #: Always a device int32 scalar: a host np.int32 default would silently
    #: type-promote when metrics from eager (host-fed) and jitted rounds are
    #: stacked across a buffered window (jnp.stack over mixed host/device
    #: scalars re-derives the dtype instead of keeping int32).
    shard_clients: jax.Array = jnp.asarray(0, jnp.int32)


class RoundMath(NamedTuple):
    """The round-MATH half of the engine: client compute for ONE shard.

    ``client_update(spec, params0, client_batch, key, cstate, sigma,
    server)``
        one client: local SGD -> flatten -> encode.
    ``group_encode(spec, params, batch, keys, cstate, mask, sigma, ...,
    server=None)``
        one shard of clients (leading axis = the mask length, vmapped):
        -> (stacked payloads, participation-masked new state, masked loss
        sum). The shard width is whatever the driver slices — a parallel
        group on the vmap path, ``shard_clients`` on the streaming path.
        ``server`` is the SHARED server-scope pipeline state
        (ServerState.comp_server, e.g. the cv server variate) — broadcast
        to every client, never sliced, updated only in the server finish.
    ``group_round(...)``
        group_encode + masked aggregation to one flat f32 SUM buffer.
    """
    client_update: Callable
    group_encode: Callable
    group_round: Callable


def init_server_state(params, cfg: FedConfig, compressor,
                      rng: jax.Array, sigma0: float = 0.0) -> ServerState:
    opt = _server_optimizer(cfg)
    spec = wire.tree_spec(params)
    cstate = compressor.init_state(spec.n_coords)
    if cstate is not None:
        # one flat state row per client per slot: (groups, n_clients, ...)
        cstate = jax.tree.map(
            lambda x: jnp.broadcast_to(
                x, (cfg.client_groups, cfg.n_clients) + x.shape), cstate)
    # shared server-scope slots (control variates): ONE tree, no client axis
    cserver = (compressor.init_server_state(spec.n_coords)
               if hasattr(compressor, "init_server_state") else None)
    return ServerState(params=params, opt_state=opt.init(params),
                       comp_state=cstate, rng=rng,
                       round=jnp.zeros((), jnp.int32),
                       sigma=jnp.asarray(sigma0, jnp.float32),
                       comp_server=cserver)


def _server_optimizer(cfg: FedConfig) -> Optimizer:
    return make_optimizer(cfg.server_opt, lr=cfg.server_lr, **dict(cfg.server_opt_kw))


class CohortPlan(NamedTuple):
    """Resolved execution plan of the round driver (see resolve_cohort)."""
    mode: str          # "vmap" | "stream"
    shard: int         # clients per stream shard (0 on the vmap plan)
    unroll: int        # lax.scan unroll of the shard loop
    devices: int       # size of the 'clients' shard_map mesh axis (1 = none)
    feed: str          # "device" | "host" shard feeding


#: the vmap plan — one vmap over the whole cohort, no device axis
VMAP_PLAN = CohortPlan("vmap", 0, 1, 1, "device")


def auto_shard_size(n_coords: int) -> int:
    """Pick the streaming shard size K from the model coordinate count and
    the per-device memory budget (context.STREAM_SHARD_BUDGET_BYTES).

    The streaming engine's per-shard working set is ~one dense f32 gradient
    per in-flight client plus its packed wire row (4*d + d/8 bytes each), so
    K = budget // (4*d + d/8), clamped to [STREAM_SHARD_MIN,
    STREAM_SHARD_MAX] and rounded down to a multiple of
    wire.SIGN_REDUCE_CLIENT_BLK. Block alignment is a throughput choice
    now, not a correctness one: the SignFoldAcc carry keeps fp32-weighted
    folds bit-reproducible at ANY shard size, but blk-aligned shards keep
    its pending-row buffer permanently empty.
    """
    if n_coords <= 0:
        return STREAM_DEFAULT_SHARD
    per_client = 4 * n_coords + n_coords // 8
    k = STREAM_SHARD_BUDGET_BYTES // per_client
    k = (k // wire.SIGN_REDUCE_CLIENT_BLK) * wire.SIGN_REDUCE_CLIENT_BLK
    return int(min(max(k, STREAM_SHARD_MIN), STREAM_SHARD_MAX))


def resolve_cohort(policy, total_clients: int, n_coords: int,
                   spmd_axes=None) -> CohortPlan:
    """CohortPolicy (or its spec string) + static round shapes -> the
    driver's CohortPlan: ("vmap", 0, 1, 1, "device") or
    ("stream", shard, unroll, devices, feed).

    THE one place the streaming auto-gate lives: ``auto`` and a bare
    ``stream`` fall back to the vmap plan below STREAM_AUTO_MIN_ELEMS
    client-coordinate elements (below the measured scan-overhead crossover;
    see context.py), while an explicit ``stream(shard=K)``, ``shard=auto``,
    ``devices=`` or ``feed=host`` always streams — the bit-identity tests
    and memory pins force the path this way at small sizes. ``shard=0`` and
    ``shard=auto`` both take the memory-budget K of ``auto_shard_size``;
    the shard is clamped to the cohort. ``devices=auto`` expands to every
    local device; the resolved count is clamped to the shard count (no
    all-padding devices) and validated against jax.device_count().

    ``spmd_axes`` is the launcher's client-axis mesh sharding (dryrun /
    multi-chip plans): when set, the client axis is already parallelized by
    the surrounding mesh, so ``auto`` resolves to the vmap plan (the shard
    scan would SERIALIZE the sharded axis and force XLA into involuntary
    rematerializations) and a forced stream policy is a config conflict —
    the streaming cohort's own device axis is ``stream(devices=D)``.
    """
    pol = CohortPolicy.parse(policy)
    if pol.mode == "vmap":
        return VMAP_PLAN
    forced = pol.mode == "stream" and (pol.shard != 0 or pol.devices != 1
                                       or pol.feed == "host")
    if spmd_axes is not None:
        if forced:
            raise ValueError(
                f"cohort policy {policy!r} forces the streaming plan, "
                f"but the launcher plan shards the client axis over mesh "
                f"axes {spmd_axes!r} — the shard scan would serialize the "
                "axis the mesh parallelizes. Drop the stream(...) policy "
                "(the mesh already provides client parallelism) or use a "
                "launcher plan without client_axes.")
        return VMAP_PLAN
    if not forced and total_clients * n_coords < STREAM_AUTO_MIN_ELEMS:
        return VMAP_PLAN
    want = (auto_shard_size(n_coords)
            if pol.shard in (0, STREAM_SHARD_AUTO) else pol.shard)
    shard = min(want, total_clients)
    if shard >= total_clients and not forced:
        return VMAP_PLAN   # one shard IS the vmap path, minus the scan
    devices = pol.devices
    if devices == COHORT_DEVICES_AUTO:
        devices = jax.device_count()
    if devices > jax.device_count():
        raise ValueError(
            f"cohort plan wants devices={devices} but only "
            f"{jax.device_count()} are visible (set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=D to "
            f"simulate a multi-device host on CPU)")
    devices = max(1, min(devices, -(-total_clients // shard)))
    return CohortPlan("stream", shard, pol.unroll, devices, pol.feed)


def iter_shards(batch, mask, cstate, *, shard: int, total: int):
    """Host-side shard feeder for ``stream(feed=host)``: yields one
    ``(s_idx, batch_s, cstate_s, mask_s)`` tuple of numpy slices per shard,
    in global shard order.

    The slices mirror the device-resident reshard of ``stream_cohort``
    exactly — the final shard wrap-pads with the cohort's first rows under a
    zero participation mask, and ``s_idx`` is the GLOBAL shard index (a
    ``np.uint32`` scalar, so the jitted per-shard kernel traces once) — which
    is what makes the host-fed round bit-identical to the device-fed one.
    The host driver ``jax.device_put``s tuple t+1 while tuple t computes
    (double buffering), so only one shard of batch/mask/state occupies
    device memory at a time.
    """
    n_shards = -(-total // shard)
    flat = lambda x: np.asarray(x).reshape((total,) + np.shape(x)[2:])
    b = jax.tree.map(flat, batch)
    m = np.asarray(mask, dtype=np.float32).reshape(total)
    c = None if cstate is None else jax.tree.map(flat, cstate)
    for s in range(n_shards):
        sl = np.arange(s * shard, (s + 1) * shard)
        rows = sl % total
        take = lambda x: x[rows]
        yield (np.uint32(s),
               jax.tree.map(take, b),
               None if c is None else jax.tree.map(take, c),
               (m[rows] * (sl < total)).astype(np.float32))


def _build_round_math(loss_fn: Callable, compressor, cfg: FedConfig, *,
                      dynamic_sigma: bool, legacy_client_path: bool,
                      spmd_axes, constrain_wire: Callable,
                      adversary=None) -> RoundMath:
    """Build the round-math half: per-shard client compute, no scheduling.

    ``adversary`` is a bound fed/adversary.py policy (or None): payload
    attacks are injected in ``group_encode`` on the ENCODED wire stack —
    after the client encode, before aggregation and state masking — so an
    EF client's residual tracks what it MEANT to send (wire-transit
    corruption semantics) and every cohort plan sees the identical attack
    (selection is by global client index + round).
    """
    gamma = cfg.client_lr

    def local_sgd(params, client_batch):
        """scan over E local steps; returns (x_E, mean loss)."""
        def step(p, b):
            loss, g = jax.value_and_grad(loss_fn)(p, b)
            p = jax.tree.map(lambda w, gw: w - gamma * gw.astype(w.dtype), p, g)
            return p, loss

        x_e, losses = jax.lax.scan(step, params, client_batch)
        return x_e, jnp.mean(losses)

    def client_update(spec, params0, client_batch, key, cstate, sigma,
                      server=None):
        if cfg.local_steps == 1 and not legacy_client_path:
            # E == 1: the pseudo-gradient (x0 - x1)/gamma IS the batch
            # gradient, so neither the updated weights nor the subtraction
            # back need to exist (and a length-1 lax.scan would lower to an
            # XLA while loop whose params-tree carry is copied at the loop
            # boundary — an (n_clients x params) copy per round for zero
            # sequencing). ~2x less client-side memory traffic around the
            # flatten on the CPU benchmark; identical up to f32 rounding
            # (this path skips the (gamma*g)/gamma round-trip).
            loss, g = jax.value_and_grad(loss_fn)(
                params0, jax.tree.map(lambda x: x[0], client_batch))
            flat = spec.flatten(g)
        else:
            x_e, loss = local_sgd(params0, client_batch)
            pseudo = jax.tree.map(
                lambda a, b: (a.astype(jnp.float32) - b.astype(jnp.float32))
                / gamma,
                params0, x_e)
            # the ONE flatten: pytree -> contiguous fp32 wire buffer
            flat = spec.flatten(pseudo)
        if cfg.dp_clip > 0.0:
            flat = clip_flat(flat, cfg.dp_clip)
        # the server/spec kwargs are capability-gated: only pipelines with
        # server-scope slots receive ``server`` and only tree-structured
        # pipelines (sigma_sched) receive ``spec`` (legacy duck-typed
        # compressors keep their three-argument encode signature)
        enc, new_cstate = compressor.encode(
            key, flat, cstate, sigma=sigma if dynamic_sigma else None,
            **({"server": server} if server is not None else {}),
            **({"spec": spec}
               if getattr(compressor, "needs_tree_spec", False) else {}))
        return enc, new_cstate, loss

    def group_encode(spec, params, group_batch, keys, group_cstate, mask_g,
                     sigma, idx_g=None, round_idx=None, server=None):
        """One shard of mask_g.shape[0] clients: returns the client-stacked
        payloads (NOT yet aggregated), the participation-masked new state,
        and the masked loss sum. ``idx_g`` is the shard's GLOBAL client
        indices and ``round_idx`` the traced round counter — only consumed
        by the adversary's payload injection (both optional: shape-probing
        eval_shape calls skip them; corruption never changes shapes).
        ``server`` is the shared server-scope pipeline state
        (ServerState.comp_server), broadcast — never sliced — across the
        shard's clients."""
        cu = lambda *a: client_update(spec, *a)
        if mask_g.shape[0] == 1:
            # sequential-client (big-arch) mode: skip the vmap — a size-1
            # vmap without spmd_axis_name drops every sharding constraint
            # inside (measured: 16 TB/dev of replicate-fallback collectives
            # on jamba; EXPERIMENTS.md §Perf).
            enc1, ncs1, loss1 = cu(
                params, jax.tree.map(lambda x: x[0], group_batch), keys[0],
                (None if group_cstate is None
                 else jax.tree.map(lambda x: x[0], group_cstate)), sigma,
                server)
            enc = jax.tree.map(lambda e: e[None], enc1)
            new_cstate = (None if ncs1 is None
                          else jax.tree.map(lambda e: e[None], ncs1))
            losses = loss1[None]
        else:
            enc, new_cstate, losses = jax.vmap(
                cu,
                in_axes=(None, 0, 0,
                         0 if group_cstate is not None else None, None,
                         None),
                spmd_axis_name=spmd_axes,
            )(params, group_batch, keys, group_cstate, sigma, server)
        if adversary is not None and idx_g is not None:
            # wire-transit corruption: the payload stack is attacked AFTER
            # the honest encode (EF residuals above stay honest) and BEFORE
            # aggregation/state masking
            enc = adversary.corrupt(enc, idx_g, round_idx)
        # participation mask: dead clients contribute zero (weight 0 in the
        # aggregate); stateful compressors keep their residual bit-exactly.
        if group_cstate is not None:
            new_cstate = jax.tree.map(
                lambda new, old: jnp.where(
                    mask_g.reshape((-1,) + (1,) * (new.ndim - 1)) > 0, new, old),
                new_cstate, group_cstate)
        # dead (and shard-padding) clients are excluded via where, not just
        # the weight product, so a non-finite loss on an excluded slot can
        # never poison the round metric
        loss_sum = jnp.sum(jnp.where(mask_g > 0, losses * mask_g, 0.0))
        return enc, new_cstate, loss_sum

    def group_round(spec, params, group_batch, keys, group_cstate, mask_g,
                    sigma, idx_g=None, round_idx=None, server=None):
        """group_encode + masked aggregation to one flat SUM accumulator."""
        enc, new_cstate, loss_sum = group_encode(
            spec, params, group_batch, keys, group_cstate, mask_g, sigma,
            idx_g, round_idx, server)
        enc_sum = constrain_wire(
            compressor.aggregate(enc, mask_g, spec.n_coords))
        return enc_sum, new_cstate, loss_sum

    return RoundMath(client_update=client_update, group_encode=group_encode,
                     group_round=group_round)


def build_round_step(loss_fn: Callable, compressor, cfg: FedConfig,
                     ctx: Optional[RoundContext] = None,
                     *, dynamic_sigma: bool = False,
                     param_constraint: Optional[Callable] = None,
                     wire_constraint: Optional[Callable] = None,
                     spmd_axes=None, agg_backend: Optional[str] = None,
                     encode_backend: Optional[str] = None,
                     weights_are_mask: bool = False,
                     legacy_client_path: bool = False):
    """Returns round_step(state, batch, mask) -> (state, RoundMetrics) —
    the round DRIVER (shard scheduling + key/batch/mask slicing) wrapped
    around the round math of ``_build_round_math``.

    loss_fn(params, batch_slice) -> scalar loss. ``batch`` is a pytree whose
    leaves have leading dims (client_groups, n_clients, E, ...). ``mask`` is a
    float (client_groups, n_clients) participation mask (straggler dropout /
    partial participation); pass all-ones for full participation.

    ``ctx`` is the typed deployment policy (core/context.py RoundContext):
    backend selection for the client fused encode and the server
    sign-reduce (``None`` keeps each stage's own setting), the static
    ``weights_are_mask`` 0/1 guarantee that unlocks the popcount
    aggregation specialization (leave False for fractional data-size
    weights), ``dynamic_sigma`` (thread the server state's traced Plateau
    sigma into the codec), ``legacy_client_path`` (restore the
    pre-fused client step — always scan over E local steps, even E == 1,
    and form the pseudo-gradient by updating the weights and subtracting
    them back — kept ONLY so the benchmark's dense baseline measures what
    the legacy round actually cost), and ``cohort`` (the execution plan:
    vmap vs the streaming massive-cohort shard scan; see the module
    docstring and ``resolve_cohort``). The engine applies the context to the
    compression pipeline ONCE here via ``Pipeline.with_context``, so kernels
    are dispatched per-stage. The keyword arguments after ``ctx`` mirror the
    pre-RoundContext API and are folded into a context when ``ctx`` is not
    given; new callers should pass a RoundContext.

    Per-client PRNG keys are derived by GLOBAL client index
    (noise.client_keys), so the vmap and streaming paths — and any shard
    size — consume identical randomness.

    ``param_constraint`` re-applies sharding constraints to params-shaped
    trees inside the step (set by the launcher). ``wire_constraint`` pins the
    aggregated flat wire buffer — the launcher passes replicate (it is 8-32x
    smaller than the params and feeds one collective) so the unflatten back
    to sharded parameter layouts is a local slice, never a reshard (see
    launch/sharding.py wire_state_specs for the per-client residual layout).
    """
    legacy_kw = dict(agg_backend=agg_backend, encode_backend=encode_backend,
                     weights_are_mask=weights_are_mask,
                     legacy_client_path=legacy_client_path,
                     dynamic_sigma=dynamic_sigma)
    if ctx is None:
        ctx = RoundContext(**legacy_kw)
    elif any(v not in (None, False) for v in legacy_kw.values()):
        raise ValueError(
            "pass the round policy either as a RoundContext or as the "
            "legacy keyword arguments, not both — the kwargs set here "
            f"would be silently ignored: "
            f"{ {k: v for k, v in legacy_kw.items() if v not in (None, False)} }")
    if hasattr(compressor, "with_context"):
        compressor = compressor.with_context(ctx)
    else:
        # duck-typed legacy compressor objects: replace matching fields
        fields = {f.name for f in dataclasses.fields(compressor)}
        overrides = {k: v for k, v in [("agg_backend", ctx.agg_backend),
                                       ("encode_backend", ctx.encode_backend)]
                     if v is not None and k in fields}
        if ctx.weights_are_mask and "weights_are_mask" in fields:
            overrides["weights_are_mask"] = True
        if overrides:
            compressor = dataclasses.replace(compressor, **overrides)
    cohort_policy = CohortPolicy.parse(ctx.cohort)
    opt = _server_optimizer(cfg)
    gamma = cfg.client_lr
    constrain = param_constraint or (lambda t: t)
    constrain_wire = wire_constraint or (lambda f: f)
    total = cfg.client_groups * cfg.n_clients
    adversary = None
    if getattr(ctx, "adversary", "none") != "none":
        from repro.fed.adversary import parse_adversary
        adversary = parse_adversary(ctx.adversary)
        if adversary is not None:
            adversary = adversary.bind(total)
    math = _build_round_math(
        loss_fn, compressor, cfg, dynamic_sigma=ctx.dynamic_sigma,
        legacy_client_path=ctx.legacy_client_path, spmd_axes=spmd_axes,
        constrain_wire=constrain_wire, adversary=adversary)
    dynamic_sigma = ctx.dynamic_sigma

    def stream_cohort(spec, params, batch, mask, cstate, sub, sigma,
                      round_idx, shard: int, unroll: int, devices: int = 1,
                      server=None):
        """The streaming massive-cohort executor: reshard the flat cohort
        into ``shard``-client slices, lax.scan them through the round math,
        and FOLD each shard's payload stack into one running wire
        accumulator — the full-cohort stack never exists; the scan carry is
        the aggregate's own output buffer (O(d/8) bytes for sign wires).

        With ``devices > 1`` the shard sequence is split into contiguous
        per-device slices over a 1-D ``clients`` mesh (shard_map): each
        device runs the identical scan on its slice (shard indices stay
        GLOBAL, so the counter-based key derivation is placement-invariant)
        and the local fp32 accumulators meet in one O(d) psum — the only
        cross-device collective of the round."""
        n_shards = -(-total // shard)
        if devices > 1:
            # pad the shard count so each device scans an equal slice;
            # all-pad shards carry a zero mask and contribute exactly 0
            n_shards = -(-n_shards // devices) * devices
        slots = n_shards * shard
        pad = slots - total

        def reshard(x):
            # (G, N, ...) -> (n_shards, shard, ...); padded slots wrap to
            # the cohort's first rows (real, finite data) under a zero
            # mask, so padding contributes exactly 0. Cyclic gather rather
            # than jnp.pad(mode="wrap"): device padding can exceed one
            # period of a small cohort.
            y = x.reshape((total,) + x.shape[2:])
            if pad:
                y = jnp.take(y, jnp.arange(slots) % total, axis=0)
            return y.reshape((n_shards, shard) + y.shape[1:])

        s_batch = jax.tree.map(reshard, batch)
        s_mask = reshard(mask) * (jnp.arange(slots)
                                  .reshape(n_shards, shard) < total)
        s_cstate = (None if cstate is None
                    else jax.tree.map(reshard, cstate))
        s_idx = jnp.arange(n_shards, dtype=jnp.uint32)
        shard0 = lambda t: (None if t is None
                            else jax.tree.map(lambda x: x[0], t))

        # wire accumulator init: fp32-weighted sign codecs hand back a
        # structured wire.SignFoldAcc (pending-row carry that makes the
        # shard fold bit-identical to one concatenated reduce at ANY shard
        # size); other routes fall back to a zero buffer shaped by the
        # codec's own aggregate output
        enc_shape = jax.eval_shape(
            lambda b, k, c, m: math.group_encode(
                spec, params, b, k, c, m, sigma, server=server)[0],
            shard0(s_batch), znoise.client_keys(sub, 0, shard),
            shard0(s_cstate), s_mask[0])
        fold0 = (compressor.fold_init(enc_shape)
                 if hasattr(compressor, "fold_init") else None)
        if fold0 is None:
            agg_shape = jax.eval_shape(
                lambda e, m: compressor.aggregate(e, m, spec.n_coords),
                enc_shape, s_mask[0])
        finalize = (compressor.fold_finalize
                    if hasattr(compressor, "fold_finalize")
                    else (lambda a: a))

        def scan_shards(params_d, sub_d, sigma_d, round_d, server_d, idx_d,
                        batch_d, cstate_d, mask_d, constrain_acc):
            acc0 = (fold0 if fold0 is not None
                    else jnp.zeros(agg_shape.shape, agg_shape.dtype))

            def body(carry, xs):
                acc, loss_acc = carry
                g_idx, batch_s, cstate_s, mask_s = xs
                # per-shard keys from the shard's GLOBAL client offset: the
                # derivation is counter-based, so the key of client j never
                # depends on the shard partition or device placement
                # (bit-identity vs vmap and vs any device count)
                keys_s = znoise.client_keys(sub_d,
                                            g_idx * jnp.uint32(shard),
                                            shard)
                idx_s = (g_idx.astype(jnp.int32) * shard
                         + jnp.arange(shard, dtype=jnp.int32))
                enc, new_cstate_s, loss_s = math.group_encode(
                    spec, params_d, batch_s, keys_s, cstate_s, mask_s,
                    sigma_d, idx_s, round_d, server_d)
                acc = compressor.aggregate(enc, mask_s, spec.n_coords,
                                           acc=acc)
                if fold0 is None:
                    # launcher wire constraints expect the flat buffer;
                    # the structured carry is constrained post-finalize
                    acc = constrain_acc(acc)
                return (acc, loss_acc + loss_s), new_cstate_s

            return jax.lax.scan(body, (acc0, jnp.zeros(())),
                                (idx_d, batch_d, cstate_d, mask_d),
                                unroll=unroll)

        if devices <= 1:
            (enc_sum, loss_sum), cstate_sh = scan_shards(
                params, sub, sigma, round_idx, server, s_idx, s_batch,
                s_cstate, s_mask, constrain_wire)
            if fold0 is not None:
                enc_sum = constrain_wire(finalize(enc_sum))
        else:
            mesh = Mesh(np.asarray(jax.devices()[:devices]), ("clients",))
            rep, shd = P(), P("clients")

            def per_device(params_d, sub_d, sigma_d, round_d, server_d,
                           idx_d, batch_d, cstate_d, mask_d):
                # launcher wire constraints name OUTER mesh axes — they
                # cannot apply inside the shard body; the post-psum result
                # is constrained by the caller instead
                (acc, loss), cstate_out = scan_shards(
                    params_d, sub_d, sigma_d, round_d, server_d, idx_d,
                    batch_d, cstate_d, mask_d, lambda a: a)
                # structured fold carries finalize BEFORE the psum: pending
                # rows are positional, not additive, and the flat fp32
                # buffer keeps the collective at one O(d) psum
                acc = finalize(acc)
                # THE cross-device reduce: one O(<= 2d) psum of the local
                # wire accumulators (f32 sum, or the int32 vote pair for
                # robust agg=) — compressed-domain all the way; the
                # per-client payload stack never crosses the interconnect
                if hasattr(compressor, "reduce_across_devices"):
                    acc = compressor.reduce_across_devices(acc, "clients")
                else:
                    acc = wire.psum_accumulator(acc, "clients")
                loss = jax.lax.psum(loss, "clients")
                return acc, loss, cstate_out

            enc_sum, loss_sum, cstate_sh = shard_map(
                per_device, mesh=mesh,
                in_specs=(rep, rep, rep, rep, rep, shd, shd, shd, shd),
                out_specs=(rep, rep, shd),
                check_rep=False,
            )(params, sub, sigma, jnp.asarray(round_idx, jnp.int32),
              server, s_idx, s_batch, s_cstate, s_mask)
            enc_sum = constrain_wire(enc_sum)
        if cstate_sh is None:
            new_cstate = None
        else:
            new_cstate = jax.tree.map(
                lambda x: x.reshape((slots,) + x.shape[2:])
                [:total].reshape((cfg.client_groups, cfg.n_clients)
                                 + x.shape[2:]),
                cstate_sh)
        return enc_sum, new_cstate, loss_sum

    def round_step(state: ServerState, batch, mask):
        spec = wire.tree_spec(state.params)
        rng, sub = jax.random.split(state.rng)
        sigma = state.sigma
        plan = resolve_cohort(cohort_policy, total, spec.n_coords,
                              spmd_axes)
        if adversary is not None:
            # mid-round dropout fires on the FULL slot mask before anything
            # else, so n_live, loss weighting and state masking all agree
            mask = adversary.drop_mask(jnp.asarray(mask, jnp.float32),
                                       state.round)

        if plan.mode == "stream":
            enc_sum, new_cstate, loss_sum = stream_cohort(
                spec, state.params, batch, mask, state.comp_state, sub,
                sigma, state.round, plan.shard, plan.unroll, plan.devices,
                server=state.comp_server)
        else:
            # per-client keys by global index — identical to the streaming
            # derivation, so the two plans are interchangeable mid-training
            all_keys = znoise.client_keys(sub, 0, total).reshape(
                cfg.client_groups, cfg.n_clients, -1)
            g_indices = jnp.arange(total, dtype=jnp.int32).reshape(
                cfg.client_groups, cfg.n_clients)
            if cfg.client_groups == 1:
                g_batch = jax.tree.map(lambda x: x[0], batch)
                g_cstate = (None if state.comp_state is None
                            else jax.tree.map(lambda x: x[0],
                                              state.comp_state))
                enc_sum, new_cstate_g, loss_sum = math.group_round(
                    spec, state.params, g_batch, all_keys[0], g_cstate,
                    mask[0], sigma, g_indices[0], state.round,
                    state.comp_server)
                new_cstate = (None if new_cstate_g is None
                              else jax.tree.map(lambda x: x[None],
                                                new_cstate_g))
            elif compressor.stacks_group_payloads():
                # NOTE a "flatten small (G, N) rounds into one G*N vmap"
                # gate was tried here (PR 7) and measured AGAINST on XLA
                # CPU: the group lax.scan costs only ~0.1-0.2 ms/step of
                # loop overhead, while widening the vmap regresses the
                # fused packed encode 8-10x (its vmapped tile loop scales
                # superlinearly in the vmapped width — G=8,N=32,d=4096:
                # flattened 420 ms vs group-scan 41 ms; see ROADMAP
                # carry-overs). The scan stays.
                # compressed-domain group scan: the scan OUTPUT is the
                # stacked wire payloads (1 bit/coord for sign families),
                # and the server runs ONE aggregate over the (G*N, ...)
                # stack — no per-group dense f32 partials ever exist.
                def body(loss_acc, xs):
                    g_batch, keys_g, cstate_g, mask_g, idx_g = xs
                    enc, new_cstate_g, loss_sum = math.group_encode(
                        spec, state.params, g_batch, keys_g, cstate_g,
                        mask_g, sigma, idx_g, state.round,
                        state.comp_server)
                    return loss_acc + loss_sum, (enc, new_cstate_g)

                loss_sum, (enc_stack, new_cstate) = jax.lax.scan(
                    body, jnp.zeros(()),
                    (batch, all_keys, state.comp_state, mask, g_indices))
                gn = cfg.client_groups * cfg.n_clients
                enc_all = jax.tree.map(
                    lambda e: e.reshape((gn,) + e.shape[2:]), enc_stack)
                enc_sum = constrain_wire(
                    compressor.aggregate(enc_all, mask.reshape(-1),
                                         spec.n_coords))
            else:
                # dense fp32 wire: accumulate the decoded group sums in the
                # scan carry (stacking G*N dense payloads would cost G*N*d
                # f32)
                def body(carry, xs):
                    enc_acc, loss_acc = carry
                    g_batch, keys_g, cstate_g, mask_g, idx_g = xs
                    enc_sum, new_cstate_g, loss_sum = math.group_round(
                        spec, state.params, g_batch, keys_g, cstate_g,
                        mask_g, sigma, idx_g, state.round,
                        state.comp_server)
                    return ((enc_acc + enc_sum, loss_acc + loss_sum),
                            new_cstate_g)

                agg_shape = jax.eval_shape(
                    lambda b, k, c, m: math.group_round(
                        spec, state.params, b, k, c, m, sigma,
                        server=state.comp_server)[0],
                    jax.tree.map(lambda x: x[0], batch), all_keys[0],
                    (None if state.comp_state is None
                     else jax.tree.map(lambda x: x[0], state.comp_state)),
                    mask[0])
                zero_enc = jnp.zeros(agg_shape.shape, agg_shape.dtype)
                (enc_sum, loss_sum), new_cstate = jax.lax.scan(
                    body, (zero_enc, jnp.zeros(())),
                    (batch, all_keys, state.comp_state, mask, g_indices))

        return _finish(state, spec, rng, sigma, enc_sum, new_cstate,
                       loss_sum, mask, plan.shard)

    def _finish(state, spec, rng, sigma, enc_sum, new_cstate, loss_sum,
                mask, shard_used):
        n_live = jnp.maximum(jnp.sum(mask), 1.0)
        sig = sigma if dynamic_sigma else None
        spec_kw = ({"spec": spec}
                   if getattr(compressor, "needs_tree_spec", False) else {})
        if hasattr(compressor, "decode_sum"):
            # the codec owns the full sum -> estimate mapping (robust agg=
            # modes decode the int32 vote pair; mean laws divide by n_live)
            g_flat = constrain_wire(
                compressor.decode_sum(enc_sum, n_live, sigma=sig, **spec_kw))
        else:
            # duck-typed legacy compressors: the mean law, spelled out
            g_flat = constrain_wire(
                compressor.decode_mean(enc_sum / n_live, sigma=sig,
                                       **spec_kw))
        # the ONE unflatten: decoded flat estimate -> params-shaped pytree
        g_hat = constrain(spec.unflatten(g_flat))
        # Algorithm 1 line 15: x_t = x_{t-1} - eta * gamma * mean(Delta)
        scaled = jax.tree.map(lambda g: gamma * g, g_hat)
        new_params, new_opt = opt.update(scaled, state.opt_state, state.params)

        # server-scope pipeline state (control variates): fold the decoded
        # mean into the shared variate — exact for mean-law codecs because
        # g_flat is the mean of the per-client local decodes (the same
        # quantity each client folded into its own row this round)
        comp_server = state.comp_server
        if comp_server is not None and hasattr(compressor, "update_server"):
            comp_server = compressor.update_server(
                comp_server, g_flat, n_live, float(total))

        metrics = RoundMetrics(
            loss=loss_sum / n_live,
            grad_est_norm=jnp.linalg.norm(g_flat[:spec.n_coords]),
            participation=n_live,
            uplink_bits=n_live * float(spec.n_coords
                                       * compressor.wire_bits_per_coord),
            shard_clients=jnp.asarray(shard_used, jnp.int32))
        new_state = ServerState(params=new_params, opt_state=new_opt,
                                comp_state=new_cstate, rng=rng,
                                round=state.round + 1, sigma=sigma,
                                comp_server=comp_server)
        return new_state, metrics

    # ---- stream(feed=host): the double-buffered host shard driver -------
    shard_fns = {}

    def _host_shard_fn(spec, shard):
        # one jitted per-shard kernel, cached across rounds; s_idx arrives
        # as a traced uint32 scalar so every shard reuses the same trace
        key = (shard, spec.n_coords)
        if key not in shard_fns:
            def fn(params, sub, sigma, server, round_idx, s_idx, batch_s,
                   cstate_s, mask_s, acc, loss_acc):
                keys_s = znoise.client_keys(sub, s_idx * jnp.uint32(shard),
                                            shard)
                idx_s = (s_idx.astype(jnp.int32) * shard
                         + jnp.arange(shard, dtype=jnp.int32))
                enc, new_cstate_s, loss_s = math.group_encode(
                    spec, params, batch_s, keys_s, cstate_s, mask_s, sigma,
                    idx_s, round_idx, server)
                acc = compressor.aggregate(enc, mask_s, spec.n_coords,
                                           acc=acc)
                if not isinstance(acc, wire.SignFoldAcc):
                    # structured carries are constrained post-finalize;
                    # launcher wire constraints expect the flat buffer
                    acc = constrain_wire(acc)
                return acc, loss_acc + loss_s, new_cstate_s
            shard_fns[key] = jax.jit(fn)
        return shard_fns[key]

    def host_round_step(state: ServerState, batch, mask):
        """Python-loop round driver for ``stream(feed=host)`` — do NOT wrap
        in jax.jit (it slices host numpy per shard). Bit-identical to the
        device-fed stream: same shard slices, same global-index keys, same
        left-fold accumulator order."""
        spec = wire.tree_spec(state.params)
        plan = resolve_cohort(cohort_policy, total, spec.n_coords,
                              spmd_axes)
        shard = plan.shard
        n_shards = -(-total // shard)
        rng, sub = jax.random.split(state.rng)
        sigma = state.sigma
        stateful = state.comp_state is not None
        if adversary is not None:
            # eager host step: materialize the dropped mask before slicing
            mask = np.asarray(adversary.drop_mask(
                jnp.asarray(mask, jnp.float32), state.round))

        gen = iter_shards(batch, mask, state.comp_state, shard=shard,
                          total=total)
        cur = jax.device_put(next(gen))
        enc_shape = jax.eval_shape(
            lambda b, k, c, m: math.group_encode(
                spec, state.params, b, k, c, m, sigma,
                server=state.comp_server)[0],
            cur[1], znoise.client_keys(sub, 0, shard), cur[2], cur[3])
        acc = (compressor.fold_init(enc_shape)
               if hasattr(compressor, "fold_init") else None)
        if acc is None:
            agg_shape = jax.eval_shape(
                lambda e, m: compressor.aggregate(e, m, spec.n_coords),
                enc_shape, cur[3])
            acc = jnp.zeros(agg_shape.shape, agg_shape.dtype)
        loss_sum = jnp.zeros(())
        fn = _host_shard_fn(spec, shard)
        rows_host, prev_rows = [], None
        for s in range(n_shards):
            # double buffer: upload shard s+1 (async dispatch) before
            # launching shard s's compute ...
            nxt = jax.device_put(next(gen)) if s + 1 < n_shards else None
            acc, loss_sum, rows = fn(state.params, sub, sigma,
                                     state.comp_server, state.round,
                                     *cur, acc, loss_sum)
            # ... and drain shard s-1's finished state rows to host while
            # shard s computes, so only one shard's tensors stay on device
            if stateful and prev_rows is not None:
                rows_host.append(jax.tree.map(np.asarray, prev_rows))
            prev_rows = rows
            cur = nxt
        if hasattr(compressor, "fold_finalize"):
            acc = constrain_wire(compressor.fold_finalize(acc)) \
                if isinstance(acc, wire.SignFoldAcc) else acc
        new_cstate = None
        if stateful:
            rows_host.append(jax.tree.map(np.asarray, prev_rows))
            stacked = jax.tree.map(lambda *rs: np.concatenate(rs, axis=0),
                                   *rows_host)
            new_cstate = jax.tree.map(
                lambda x: x[:total].reshape(
                    (cfg.client_groups, cfg.n_clients) + x.shape[1:]),
                stacked)
        return _finish(state, spec, rng, sigma, acc, new_cstate, loss_sum,
                       jnp.asarray(mask), plan.shard)

    # ---- round_mode=async(...): the deadline-fold driver ----------------
    mode_policy = RoundModePolicy.parse(getattr(ctx, "round_mode", "sync"))
    if mode_policy.mode == "async":
        # the async driver reuses this builder's internals wholesale — the
        # round math, the _finish decode closure, the bound adversary —
        # so its shard pass is the sync host driver's computation exactly
        # (the zero-latency bit-identity pin of tests/test_async_server.py)
        from repro.fed.async_server import build_async_round_step
        return build_async_round_step(
            policy=mode_policy, latency_spec=getattr(ctx, "latency", "zero"),
            compressor=compressor, cfg=cfg, round_math=math, finish=_finish,
            constrain_wire=constrain_wire, cohort_policy=cohort_policy,
            adversary=adversary, total=total)

    return host_round_step if cohort_policy.feed == "host" else round_step


def make_batch_spec(cfg: FedConfig, per_step_batch: dict) -> dict:
    """Shape helper: expand a single-step batch spec to the round layout
    (groups, n_clients, E, ...)."""
    lead = (cfg.client_groups, cfg.n_clients, cfg.local_steps)
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(lead + s.shape, s.dtype), per_step_batch)
