"""z-SignFedAvg round engine (paper Algorithm 1, plus every baseline).

One *round step* is a single jitted function:

    broadcast server params -> vmap over parallel clients:
        scan over E local SGD steps -> pseudo-gradient (x0 - xE)/gamma
        -> flatten ONCE to a 1-D fp32 wire buffer (core/wire.TreeSpec)
        -> compressor.encode  (the bitpacked 1-bit uplink payload)
    -> participation-masked flat aggregation over the client axis
       (uint8 collective + fused weighted sign-reduce == the compressed
       all-reduce; sign families never re-inflate the dense sign matrix)
    -> compressor.decode_mean -> unflatten ONCE -> server optimizer update.

The engine never touches per-leaf encodings: every compression Pipeline
(core/compression.py) speaks the flat wire-buffer codec of core/wire.py, so
there are no compressor-specific branches here — sign families ship
bitpacked uint8, top-k ships COO pairs, identity ships fp32, all through the
same four calls. Deployment policy (backend selection, mask guarantees,
dynamic sigma, legacy paths, cohort execution) arrives as ONE typed value —
the RoundContext of core/context.py — applied to the pipeline at build time.

The engine is split in two halves:

ROUND MATH (``_build_round_math``) — per-shard client compute: the local-SGD
scan, the fused encode, and the participation-masked state update for one
slice of clients, vmapped over that slice's leading axis. Pure in the shard:
it never knows how many shards exist or how they are scheduled.

ROUND DRIVER (``build_round_step``) — shard scheduling and slicing: derives
per-client PRNG keys by GLOBAL client index (noise.client_keys — a counter
derivation, so results are invariant to how the cohort is partitioned),
slices batch/mask/state per shard, and aggregates. ``RoundContext.cohort``
picks the walk:

  ``vmap``    one vmap over all ``n_clients`` parallel clients; sequential
              client *groups* are an outer ``lax.scan``. For compressed wire
              layouts the scan emits the raw payload stack as its OUTPUT and
              the server runs ONE ``aggregate`` over the (client_groups *
              n_clients, n_bytes) stack; dense fp32 layouts accumulate the
              decoded group sums in the scan carry (the choice is the
              compressor's ``stacks_group_payloads()``).
  ``stream``  the massive-cohort executor: the flat cohort of
              ``client_groups * n_clients`` clients is resharded into
              ``shard``-client slices and scanned, folding each shard's
              payload stack into ONE running wire accumulator via
              ``Pipeline.aggregate(..., acc=...)`` (reduce-as-you-go — a
              full-cohort payload stack never exists). Peak memory is O(d)
              model + O(shard * E * batch) data + O(shard * d/8) wire for
              sign families (one (d,) f32 carry for dense codecs), for ANY
              cohort size. Bit-identical to the vmap path for 0/1 masks
              (integer sign sums — any shard size) and for fp32-weighted
              (EF) aggregation at shard sizes that are multiples of
              wire.SIGN_REDUCE_CLIENT_BLK; see wire.unpack_sum.
  ``auto``    stream iff ``total_clients * n_coords`` reaches
              context.STREAM_AUTO_MIN_ELEMS — small rounds keep the vmap
              path (lax.scan costs ~30-80 ms/round of loop overhead on XLA
              CPU), huge cohorts get the O(wire) memory contract. A bare
              ``stream`` gates the same way; ``stream(shard=K)`` forces.

Per-client compressor state (EF / top-k residuals) is a flat fp32 buffer of
shape (client_groups, n_clients, n_coords); dead clients keep their previous
residual bit-exactly (the state update is participation-masked). When the
cohort does not divide the shard size, the last shard is padded with
wrapped-around batch rows under a zero participation mask — padded slots
contribute exactly nothing and their state rows are discarded.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import noise as znoise
from repro.core import wire
from repro.core.context import (STREAM_AUTO_MIN_ELEMS, STREAM_DEFAULT_SHARD,
                                CohortPolicy, RoundContext)
from repro.core.dp import clip_flat
from repro.optim.optimizers import Optimizer, make_optimizer


@dataclasses.dataclass(frozen=True)
class FedConfig:
    n_clients: int = 8            # parallel clients (vmapped / mesh-sharded)
    client_groups: int = 1        # sequential groups; total clients = n*groups
    local_steps: int = 1          # E
    client_lr: float = 0.01       # gamma
    server_lr: float = 1.0        # eta (decode already applies eta_z * sigma)
    server_opt: str = "sgd"       # sgd | momentum | adam
    server_opt_kw: tuple = ()     # e.g. (("momentum", 0.9),)
    dp_clip: float = 0.0          # >0 enables DP-SignFedAvg clipping (Alg. 2)


class ServerState(NamedTuple):
    params: Any
    opt_state: Any
    comp_state: Any       # flat per-client residuals, (G, N, n_coords) or None
    rng: jax.Array
    round: jax.Array      # int32 scalar
    sigma: jax.Array      # dynamic noise scale (Plateau criterion)


class RoundMetrics(NamedTuple):
    loss: jax.Array
    grad_est_norm: jax.Array
    participation: jax.Array
    uplink_bits: jax.Array


class RoundMath(NamedTuple):
    """The round-MATH half of the engine: client compute for ONE shard.

    ``client_update(spec, params0, client_batch, key, cstate, sigma)``
        one client: local SGD -> flatten -> encode.
    ``group_encode(spec, params, batch, keys, cstate, mask, sigma)``
        one shard of clients (leading axis = the mask length, vmapped):
        -> (stacked payloads, participation-masked new state, masked loss
        sum). The shard width is whatever the driver slices — a parallel
        group on the vmap path, ``shard_clients`` on the streaming path.
    ``group_round(...)``
        group_encode + masked aggregation to one flat f32 SUM buffer.
    """
    client_update: Callable
    group_encode: Callable
    group_round: Callable


def init_server_state(params, cfg: FedConfig, compressor,
                      rng: jax.Array, sigma0: float = 0.0) -> ServerState:
    opt = _server_optimizer(cfg)
    spec = wire.tree_spec(params)
    cstate = compressor.init_state(spec.n_coords)
    if cstate is not None:
        # one flat residual buffer per client: (groups, n_clients, n_coords)
        cstate = jax.tree.map(
            lambda x: jnp.broadcast_to(
                x, (cfg.client_groups, cfg.n_clients) + x.shape), cstate)
    return ServerState(params=params, opt_state=opt.init(params),
                       comp_state=cstate, rng=rng,
                       round=jnp.zeros((), jnp.int32),
                       sigma=jnp.asarray(sigma0, jnp.float32))


def _server_optimizer(cfg: FedConfig) -> Optimizer:
    return make_optimizer(cfg.server_opt, lr=cfg.server_lr, **dict(cfg.server_opt_kw))


def resolve_cohort(policy, total_clients: int, n_coords: int):
    """CohortPolicy (or its spec string) + static round shapes -> the
    driver's execution plan: ("vmap", 0, 1) or ("stream", shard, unroll).

    THE one place the streaming auto-gate lives: ``auto`` and a bare
    ``stream`` fall back to the vmap path below STREAM_AUTO_MIN_ELEMS
    client-coordinate elements (where the shard scan's ~30-80 ms/round XLA
    CPU loop overhead would dominate), while an explicit ``stream(shard=K)``
    always streams — the bit-identity tests and memory pins force the path
    this way at small sizes. The shard size is clamped to the cohort.
    """
    pol = CohortPolicy.parse(policy)
    if pol.mode == "vmap":
        return ("vmap", 0, 1)
    forced = pol.mode == "stream" and pol.shard > 0
    if not forced and total_clients * n_coords < STREAM_AUTO_MIN_ELEMS:
        return ("vmap", 0, 1)
    shard = min(pol.shard or STREAM_DEFAULT_SHARD, total_clients)
    if shard >= total_clients and not forced:
        return ("vmap", 0, 1)   # one shard IS the vmap path, minus the scan
    return ("stream", shard, pol.unroll)


def _build_round_math(loss_fn: Callable, compressor, cfg: FedConfig, *,
                      dynamic_sigma: bool, legacy_client_path: bool,
                      spmd_axes, constrain_wire: Callable) -> RoundMath:
    """Build the round-math half: per-shard client compute, no scheduling."""
    gamma = cfg.client_lr

    def local_sgd(params, client_batch):
        """scan over E local steps; returns (x_E, mean loss)."""
        def step(p, b):
            loss, g = jax.value_and_grad(loss_fn)(p, b)
            p = jax.tree.map(lambda w, gw: w - gamma * gw.astype(w.dtype), p, g)
            return p, loss

        x_e, losses = jax.lax.scan(step, params, client_batch)
        return x_e, jnp.mean(losses)

    def client_update(spec, params0, client_batch, key, cstate, sigma):
        if cfg.local_steps == 1 and not legacy_client_path:
            # E == 1: the pseudo-gradient (x0 - x1)/gamma IS the batch
            # gradient, so neither the updated weights nor the subtraction
            # back need to exist (and a length-1 lax.scan would lower to an
            # XLA while loop whose params-tree carry is copied at the loop
            # boundary — an (n_clients x params) copy per round for zero
            # sequencing). ~2x less client-side memory traffic around the
            # flatten on the CPU benchmark; identical up to f32 rounding
            # (this path skips the (gamma*g)/gamma round-trip).
            loss, g = jax.value_and_grad(loss_fn)(
                params0, jax.tree.map(lambda x: x[0], client_batch))
            flat = spec.flatten(g)
        else:
            x_e, loss = local_sgd(params0, client_batch)
            pseudo = jax.tree.map(
                lambda a, b: (a.astype(jnp.float32) - b.astype(jnp.float32))
                / gamma,
                params0, x_e)
            # the ONE flatten: pytree -> contiguous fp32 wire buffer
            flat = spec.flatten(pseudo)
        if cfg.dp_clip > 0.0:
            flat = clip_flat(flat, cfg.dp_clip)
        enc, new_cstate = compressor.encode(
            key, flat, cstate, sigma=sigma if dynamic_sigma else None)
        return enc, new_cstate, loss

    def group_encode(spec, params, group_batch, keys, group_cstate, mask_g,
                     sigma):
        """One shard of mask_g.shape[0] clients: returns the client-stacked
        payloads (NOT yet aggregated), the participation-masked new state,
        and the masked loss sum."""
        cu = lambda *a: client_update(spec, *a)
        if mask_g.shape[0] == 1:
            # sequential-client (big-arch) mode: skip the vmap — a size-1
            # vmap without spmd_axis_name drops every sharding constraint
            # inside (measured: 16 TB/dev of replicate-fallback collectives
            # on jamba; EXPERIMENTS.md §Perf).
            enc1, ncs1, loss1 = cu(
                params, jax.tree.map(lambda x: x[0], group_batch), keys[0],
                (None if group_cstate is None
                 else jax.tree.map(lambda x: x[0], group_cstate)), sigma)
            enc = jax.tree.map(lambda e: e[None], enc1)
            new_cstate = (None if ncs1 is None
                          else jax.tree.map(lambda e: e[None], ncs1))
            losses = loss1[None]
        else:
            enc, new_cstate, losses = jax.vmap(
                cu,
                in_axes=(None, 0, 0,
                         0 if group_cstate is not None else None, None),
                spmd_axis_name=spmd_axes,
            )(params, group_batch, keys, group_cstate, sigma)
        # participation mask: dead clients contribute zero (weight 0 in the
        # aggregate); stateful compressors keep their residual bit-exactly.
        if group_cstate is not None:
            new_cstate = jax.tree.map(
                lambda new, old: jnp.where(
                    mask_g.reshape((-1,) + (1,) * (new.ndim - 1)) > 0, new, old),
                new_cstate, group_cstate)
        # dead (and shard-padding) clients are excluded via where, not just
        # the weight product, so a non-finite loss on an excluded slot can
        # never poison the round metric
        loss_sum = jnp.sum(jnp.where(mask_g > 0, losses * mask_g, 0.0))
        return enc, new_cstate, loss_sum

    def group_round(spec, params, group_batch, keys, group_cstate, mask_g,
                    sigma):
        """group_encode + masked aggregation to one flat fp32 SUM buffer."""
        enc, new_cstate, loss_sum = group_encode(
            spec, params, group_batch, keys, group_cstate, mask_g, sigma)
        enc_sum = constrain_wire(
            compressor.aggregate(enc, mask_g, spec.n_coords))
        return enc_sum, new_cstate, loss_sum

    return RoundMath(client_update=client_update, group_encode=group_encode,
                     group_round=group_round)


def build_round_step(loss_fn: Callable, compressor, cfg: FedConfig,
                     ctx: Optional[RoundContext] = None,
                     *, dynamic_sigma: bool = False,
                     param_constraint: Optional[Callable] = None,
                     wire_constraint: Optional[Callable] = None,
                     spmd_axes=None, agg_backend: Optional[str] = None,
                     encode_backend: Optional[str] = None,
                     weights_are_mask: bool = False,
                     legacy_client_path: bool = False):
    """Returns round_step(state, batch, mask) -> (state, RoundMetrics) —
    the round DRIVER (shard scheduling + key/batch/mask slicing) wrapped
    around the round math of ``_build_round_math``.

    loss_fn(params, batch_slice) -> scalar loss. ``batch`` is a pytree whose
    leaves have leading dims (client_groups, n_clients, E, ...). ``mask`` is a
    float (client_groups, n_clients) participation mask (straggler dropout /
    partial participation); pass all-ones for full participation.

    ``ctx`` is the typed deployment policy (core/context.py RoundContext):
    backend selection for the client fused encode and the server
    sign-reduce (``None`` keeps each stage's own setting), the static
    ``weights_are_mask`` 0/1 guarantee that unlocks the popcount
    aggregation specialization (leave False for fractional data-size
    weights), ``dynamic_sigma`` (thread the server state's traced Plateau
    sigma into the codec), ``legacy_client_path`` (restore the
    pre-fused client step — always scan over E local steps, even E == 1,
    and form the pseudo-gradient by updating the weights and subtracting
    them back — kept ONLY so the benchmark's dense baseline measures what
    the legacy round actually cost), and ``cohort`` (the execution plan:
    vmap vs the streaming massive-cohort shard scan; see the module
    docstring and ``resolve_cohort``). The engine applies the context to the
    compression pipeline ONCE here via ``Pipeline.with_context``, so kernels
    are dispatched per-stage. The keyword arguments after ``ctx`` mirror the
    pre-RoundContext API and are folded into a context when ``ctx`` is not
    given; new callers should pass a RoundContext.

    Per-client PRNG keys are derived by GLOBAL client index
    (noise.client_keys), so the vmap and streaming paths — and any shard
    size — consume identical randomness.

    ``param_constraint`` re-applies sharding constraints to params-shaped
    trees inside the step (set by the launcher). ``wire_constraint`` pins the
    aggregated flat wire buffer — the launcher passes replicate (it is 8-32x
    smaller than the params and feeds one collective) so the unflatten back
    to sharded parameter layouts is a local slice, never a reshard (see
    launch/sharding.py wire_state_specs for the per-client residual layout).
    """
    legacy_kw = dict(agg_backend=agg_backend, encode_backend=encode_backend,
                     weights_are_mask=weights_are_mask,
                     legacy_client_path=legacy_client_path,
                     dynamic_sigma=dynamic_sigma)
    if ctx is None:
        ctx = RoundContext(**legacy_kw)
    elif any(v not in (None, False) for v in legacy_kw.values()):
        raise ValueError(
            "pass the round policy either as a RoundContext or as the "
            "legacy keyword arguments, not both — the kwargs set here "
            f"would be silently ignored: "
            f"{ {k: v for k, v in legacy_kw.items() if v not in (None, False)} }")
    if hasattr(compressor, "with_context"):
        compressor = compressor.with_context(ctx)
    else:
        # duck-typed legacy compressor objects: replace matching fields
        fields = {f.name for f in dataclasses.fields(compressor)}
        overrides = {k: v for k, v in [("agg_backend", ctx.agg_backend),
                                       ("encode_backend", ctx.encode_backend)]
                     if v is not None and k in fields}
        if ctx.weights_are_mask and "weights_are_mask" in fields:
            overrides["weights_are_mask"] = True
        if overrides:
            compressor = dataclasses.replace(compressor, **overrides)
    cohort_policy = CohortPolicy.parse(ctx.cohort)
    opt = _server_optimizer(cfg)
    gamma = cfg.client_lr
    constrain = param_constraint or (lambda t: t)
    constrain_wire = wire_constraint or (lambda f: f)
    math = _build_round_math(
        loss_fn, compressor, cfg, dynamic_sigma=ctx.dynamic_sigma,
        legacy_client_path=ctx.legacy_client_path, spmd_axes=spmd_axes,
        constrain_wire=constrain_wire)
    dynamic_sigma = ctx.dynamic_sigma
    total = cfg.client_groups * cfg.n_clients

    def stream_cohort(spec, params, batch, mask, cstate, sub, sigma,
                      shard: int, unroll: int):
        """The streaming massive-cohort executor: reshard the flat cohort
        into ``shard``-client slices, lax.scan them through the round math,
        and FOLD each shard's payload stack into one running wire
        accumulator — the full-cohort stack never exists; the scan carry is
        the aggregate's own output buffer (O(d/8) bytes for sign wires)."""
        n_shards = -(-total // shard)
        pad = n_shards * shard - total

        def reshard(x):
            # (G, N, ...) -> (n_shards, shard, ...); the last shard is
            # padded by wrapping to the cohort's first rows (real, finite
            # data) under a zero mask, so padding contributes exactly 0
            y = x.reshape((total,) + x.shape[2:])
            if pad:
                y = jnp.pad(y, ((0, pad),) + ((0, 0),) * (y.ndim - 1),
                            mode="wrap")
            return y.reshape((n_shards, shard) + y.shape[1:])

        s_batch = jax.tree.map(reshard, batch)
        s_mask = reshard(mask) * (jnp.arange(n_shards * shard)
                                  .reshape(n_shards, shard) < total)
        s_cstate = (None if cstate is None
                    else jax.tree.map(reshard, cstate))
        shard0 = lambda t: (None if t is None
                            else jax.tree.map(lambda x: x[0], t))

        # zero-init wire accumulator, shaped by the codec's own aggregate
        agg_shape = jax.eval_shape(
            lambda b, k, c, m: compressor.aggregate(
                math.group_encode(spec, params, b, k, c, m, sigma)[0],
                m, spec.n_coords),
            shard0(s_batch), znoise.client_keys(sub, 0, shard),
            shard0(s_cstate), s_mask[0])
        acc0 = jnp.zeros(agg_shape.shape, agg_shape.dtype)

        def body(carry, xs):
            acc, loss_acc = carry
            s_idx, batch_s, cstate_s, mask_s = xs
            # per-shard keys from the shard's global client offset: the
            # derivation is counter-based, so the key of client j never
            # depends on the shard partition (bit-identity vs vmap)
            keys_s = znoise.client_keys(sub, s_idx * jnp.uint32(shard),
                                        shard)
            enc, new_cstate_s, loss_s = math.group_encode(
                spec, params, batch_s, keys_s, cstate_s, mask_s, sigma)
            acc = constrain_wire(compressor.aggregate(
                enc, mask_s, spec.n_coords, acc=acc))
            return (acc, loss_acc + loss_s), new_cstate_s

        (enc_sum, loss_sum), cstate_sh = jax.lax.scan(
            body, (acc0, jnp.zeros(())),
            (jnp.arange(n_shards, dtype=jnp.uint32), s_batch, s_cstate,
             s_mask),
            unroll=unroll)
        if cstate_sh is None:
            new_cstate = None
        else:
            new_cstate = jax.tree.map(
                lambda x: x.reshape((n_shards * shard,) + x.shape[2:])
                [:total].reshape((cfg.client_groups, cfg.n_clients)
                                 + x.shape[2:]),
                cstate_sh)
        return enc_sum, new_cstate, loss_sum

    def round_step(state: ServerState, batch, mask):
        spec = wire.tree_spec(state.params)
        rng, sub = jax.random.split(state.rng)
        sigma = state.sigma
        plan, shard, unroll = resolve_cohort(cohort_policy, total,
                                             spec.n_coords)

        if plan == "stream":
            enc_sum, new_cstate, loss_sum = stream_cohort(
                spec, state.params, batch, mask, state.comp_state, sub,
                sigma, shard, unroll)
        else:
            # per-client keys by global index — identical to the streaming
            # derivation, so the two plans are interchangeable mid-training
            all_keys = znoise.client_keys(sub, 0, total).reshape(
                cfg.client_groups, cfg.n_clients, -1)
            if cfg.client_groups == 1:
                g_batch = jax.tree.map(lambda x: x[0], batch)
                g_cstate = (None if state.comp_state is None
                            else jax.tree.map(lambda x: x[0],
                                              state.comp_state))
                enc_sum, new_cstate_g, loss_sum = math.group_round(
                    spec, state.params, g_batch, all_keys[0], g_cstate,
                    mask[0], sigma)
                new_cstate = (None if new_cstate_g is None
                              else jax.tree.map(lambda x: x[None],
                                                new_cstate_g))
            elif compressor.stacks_group_payloads():
                # compressed-domain group scan: the scan OUTPUT is the
                # stacked wire payloads (1 bit/coord for sign families),
                # and the server runs ONE aggregate over the (G*N, ...)
                # stack — no per-group dense f32 partials ever exist.
                def body(loss_acc, xs):
                    g_batch, keys_g, cstate_g, mask_g = xs
                    enc, new_cstate_g, loss_sum = math.group_encode(
                        spec, state.params, g_batch, keys_g, cstate_g,
                        mask_g, sigma)
                    return loss_acc + loss_sum, (enc, new_cstate_g)

                loss_sum, (enc_stack, new_cstate) = jax.lax.scan(
                    body, jnp.zeros(()),
                    (batch, all_keys, state.comp_state, mask))
                gn = cfg.client_groups * cfg.n_clients
                enc_all = jax.tree.map(
                    lambda e: e.reshape((gn,) + e.shape[2:]), enc_stack)
                enc_sum = constrain_wire(
                    compressor.aggregate(enc_all, mask.reshape(-1),
                                         spec.n_coords))
            else:
                # dense fp32 wire: accumulate the decoded group sums in the
                # scan carry (stacking G*N dense payloads would cost G*N*d
                # f32)
                def body(carry, xs):
                    enc_acc, loss_acc = carry
                    g_batch, keys_g, cstate_g, mask_g = xs
                    enc_sum, new_cstate_g, loss_sum = math.group_round(
                        spec, state.params, g_batch, keys_g, cstate_g,
                        mask_g, sigma)
                    return ((enc_acc + enc_sum, loss_acc + loss_sum),
                            new_cstate_g)

                agg_shape = jax.eval_shape(
                    lambda b, k, c, m: math.group_round(
                        spec, state.params, b, k, c, m, sigma)[0],
                    jax.tree.map(lambda x: x[0], batch), all_keys[0],
                    (None if state.comp_state is None
                     else jax.tree.map(lambda x: x[0], state.comp_state)),
                    mask[0])
                zero_enc = jnp.zeros(agg_shape.shape, agg_shape.dtype)
                (enc_sum, loss_sum), new_cstate = jax.lax.scan(
                    body, (zero_enc, jnp.zeros(())),
                    (batch, all_keys, state.comp_state, mask))

        n_live = jnp.maximum(jnp.sum(mask), 1.0)
        g_flat = constrain_wire(compressor.decode_mean(
            enc_sum / n_live, sigma=sigma if dynamic_sigma else None))
        # the ONE unflatten: decoded flat estimate -> params-shaped pytree
        g_hat = constrain(spec.unflatten(g_flat))
        # Algorithm 1 line 15: x_t = x_{t-1} - eta * gamma * mean(Delta)
        scaled = jax.tree.map(lambda g: gamma * g, g_hat)
        new_params, new_opt = opt.update(scaled, state.opt_state, state.params)

        metrics = RoundMetrics(
            loss=loss_sum / n_live,
            grad_est_norm=jnp.linalg.norm(g_flat[:spec.n_coords]),
            participation=n_live,
            uplink_bits=n_live * float(spec.n_coords
                                       * compressor.wire_bits_per_coord))
        new_state = ServerState(params=new_params, opt_state=new_opt,
                                comp_state=new_cstate, rng=rng,
                                round=state.round + 1, sigma=sigma)
        return new_state, metrics

    return round_step


def make_batch_spec(cfg: FedConfig, per_step_batch: dict) -> dict:
    """Shape helper: expand a single-step batch spec to the round layout
    (groups, n_clients, E, ...)."""
    lead = (cfg.client_groups, cfg.n_clients, cfg.local_steps)
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(lead + s.shape, s.dtype), per_step_batch)
