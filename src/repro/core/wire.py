"""Flat wire-buffer codec substrate: flatten once, compress flat, unflatten once.

Every compressor in core/compression.py encodes/decodes a SINGLE contiguous
1-D fp32 buffer — the layout a real compressed all-reduce transmits, and the
layout the Pallas kernels (kernels/zsign, kernels/efsign) consume directly.
The round engine (core/fedavg.py) flattens the pseudo-gradient pytree exactly
once per client via :class:`TreeSpec`, and unflattens the decoded server
estimate exactly once per round. Nothing in between ever sees a pytree.

Key pieces:

  ``TreeSpec``     cached flatten metadata (treedef + leaf shapes/offsets).
                   Built at trace time; ``flatten``/``unflatten`` are the only
                   tree <-> buffer conversions in the whole round step.
  ``WireFormat``   what actually crosses the network for one client:
                   wire dtype, bits per coordinate, payload layout name.
  ``pack_signs`` / ``unpack_signs``
                   the pure-jnp 8:1 bitpack shared by every sign-family
                   compressor (the Pallas kernel in kernels/zsign is the
                   fused fast path, bit-for-bit identical — see tests).

Wire-size accounting: ``WireFormat.bits_per_coord`` is the *logical* cost per
model coordinate (1.0 for bitpacked signs, 32.0 for dense fp32, 64*frac for
COO top-k). Uplink metrics multiply it by the true coordinate count
``TreeSpec.n_coords``, not the padded buffer length, so padding to the pack
boundary (8) or the kernel tile (8192) never inflates reported bits.

Buffers may be longer than ``n_coords`` (pack/tile padding); ``unflatten``
reads only the leading ``n_coords`` entries, so decoders can hand back padded
buffers unsliced.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class WireFormat:
    """Describes one client's uplink payload.

    dtype:          numpy-style name of the dtype on the wire ("uint8" for
                    bitpacked signs, "float32" for dense).
    bits_per_coord: logical uplink bits per model coordinate (excludes
                    padding; includes per-tensor side info such as the EF
                    scale, which is O(1) and amortizes to ~0 per coord).
    layout:         payload layout name — "dense" | "bitpacked" |
                    "bitpacked+scale" | "sparse_coo".
    """
    dtype: str
    bits_per_coord: float
    layout: str


@dataclasses.dataclass(frozen=True)
class TreeSpec:
    """Flatten-once metadata for a fixed pytree structure.

    Holds the treedef plus per-leaf (shape, offset) so ``flatten`` and
    ``unflatten`` are single concatenate / slice+reshape passes. Construction
    happens at trace time (shapes are static), so the spec costs nothing
    inside jit.
    """
    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    offsets: Tuple[int, ...]
    n_coords: int

    @classmethod
    def from_tree(cls, tree) -> "TreeSpec":
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        shapes, offsets, off = [], [], 0
        for l in leaves:
            shapes.append(tuple(l.shape))
            offsets.append(off)
            n = 1
            for d in l.shape:
                n *= int(d)
            off += n
        return cls(treedef=treedef, shapes=tuple(shapes),
                   offsets=tuple(offsets), n_coords=off)

    def flatten(self, tree) -> jax.Array:
        """pytree -> (n_coords,) float32 buffer (the one flatten per round)."""
        leaves = jax.tree_util.tree_leaves(tree)
        return jnp.concatenate(
            [l.astype(jnp.float32).reshape(-1) for l in leaves])

    def unflatten(self, flat: jax.Array):
        """(>= n_coords,) buffer -> pytree of float32 leaves.

        Accepts padded buffers: only the leading ``n_coords`` entries are
        read, so sign decoders never need to slice off pack/tile padding.
        """
        leaves = []
        for shape, off in zip(self.shapes, self.offsets):
            n = 1
            for d in shape:
                n *= d
            leaves.append(jax.lax.dynamic_slice_in_dim(flat, off, n)
                          .reshape(shape))
        return jax.tree_util.tree_unflatten(self.treedef, leaves)


def tree_spec(tree) -> TreeSpec:
    return TreeSpec.from_tree(tree)


# ---------------------------------------------------------------------------
# sign bitpacking (pure-jnp reference path, little-endian bit order; the
# Pallas kernel in kernels/zsign produces the identical byte stream)
# ---------------------------------------------------------------------------

def pack_signs(signs_i8: jax.Array) -> jax.Array:
    """int8 {-1,+1} (flat, len % 8 == 0) -> uint8 bitfield of len/8."""
    bits = (signs_i8 > 0).astype(jnp.uint8).reshape(-1, 8)
    weights = (jnp.uint8(1) << jnp.arange(8, dtype=jnp.uint8))
    return jnp.sum(bits * weights, axis=-1).astype(jnp.uint8)


def unpack_signs(packed: jax.Array) -> jax.Array:
    """uint8 bitfield -> int8 {-1,+1} of len*8."""
    weights = (jnp.uint8(1) << jnp.arange(8, dtype=jnp.uint8))
    bits = (packed[:, None] & weights) > 0
    return jnp.where(bits, jnp.int8(1), jnp.int8(-1)).reshape(-1)


def pad_to(x: jax.Array, mult: int) -> jax.Array:
    r = (-x.shape[0]) % mult
    return jnp.pad(x, (0, r)) if r else x


def pack_flat(flat: jax.Array) -> jax.Array:
    """(d,) f32 -> bitpacked uint8 of ceil(d/8): bit = flat[i] >= 0.

    Zero-padded tail packs as +1 bits; harmless because ``TreeSpec.unflatten``
    never reads past n_coords.
    """
    y = pad_to(flat, 8)
    return pack_signs(jnp.where(y >= 0, jnp.int8(1), jnp.int8(-1)))


def unpack_sum(packed: jax.Array, weights: jax.Array) -> jax.Array:
    """(n_clients, n_bytes) u8, (n_clients,) f32 -> (8*n_bytes,) weighted sum
    of the +/-1 signs — the server side of the 1-bit all-gather."""
    signs = jax.vmap(unpack_signs)(packed).astype(jnp.float32)
    return jnp.einsum("nd,n->d", signs, weights)
