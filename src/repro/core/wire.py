"""Flat wire-buffer codec substrate: flatten once, compress flat, unflatten once.

Every compressor in core/compression.py encodes/decodes a SINGLE contiguous
1-D fp32 buffer — the layout a real compressed all-reduce transmits, and the
layout the Pallas kernels (kernels/zsign, kernels/efsign) consume directly.
The round engine (core/fedavg.py) flattens the pseudo-gradient pytree exactly
once per client via :class:`TreeSpec`, and unflattens the decoded server
estimate exactly once per round. Nothing in between ever sees a pytree.

Key pieces:

  ``TreeSpec``     cached flatten metadata (treedef + leaf shapes/offsets).
                   Built at trace time; ``flatten``/``unflatten`` are the only
                   tree <-> buffer conversions in the whole round step.
  ``WireFormat``   what actually crosses the network for one client:
                   wire dtype, bits per coordinate, payload layout name.
  ``pack_signs`` / ``unpack_signs``
                   the pure-jnp 8:1 bitpack shared by every sign-family
                   compressor (the Pallas kernel in kernels/zsign is the
                   fused fast path, bit-for-bit identical — see tests).
  ``unpack_sum`` / ``unpack_sum_mask``
                   the server side of the 1-bit uplink: weighted sign sum
                   computed directly on the packed bytes (butterfly bit-
                   transpose, then weighted-LUT gather / popcount), never
                   materializing the dense (n_clients, d) fp32 sign matrix.
                   These are the CPU paths; the Pallas ``sign_reduce`` kernel
                   (kernels/zsign) is the TPU fast path, bit-identical by
                   construction (same blocked client accumulation order).

Wire-size accounting: ``WireFormat.bits_per_coord`` is the *logical* cost per
model coordinate (1.0 for bitpacked signs, 32.0 for dense fp32, 64*frac for
COO top-k). Uplink metrics multiply it by the true coordinate count
``TreeSpec.n_coords``, not the padded buffer length, so padding to the pack
boundary (8) or the kernel tile (8192) never inflates reported bits.

Buffers may be longer than ``n_coords`` (pack/tile padding); ``unflatten``
reads only the leading ``n_coords`` entries, so decoders can hand back padded
buffers unsliced.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import checkify


@dataclasses.dataclass(frozen=True)
class WireFormat:
    """Describes one client's uplink payload.

    dtype:          numpy-style name of the dtype on the wire ("uint8" for
                    bitpacked signs, "float32" for dense).
    bits_per_coord: logical uplink bits per model coordinate (excludes
                    padding; includes per-tensor side info such as the EF
                    scale, which is O(1) and amortizes to ~0 per coord).
    layout:         payload layout name — "dense" | "bitpacked" |
                    "bitpacked+scale" | "sparse_coo".
    """
    dtype: str
    bits_per_coord: float
    layout: str


@dataclasses.dataclass(frozen=True)
class TreeSpec:
    """Flatten-once metadata for a fixed pytree structure.

    Holds the treedef plus per-leaf (shape, offset) so ``flatten`` and
    ``unflatten`` are single concatenate / slice+reshape passes. Construction
    happens at trace time (shapes are static), so the spec costs nothing
    inside jit.
    """
    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    offsets: Tuple[int, ...]
    n_coords: int

    @classmethod
    def from_tree(cls, tree) -> "TreeSpec":
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        shapes, offsets, off = [], [], 0
        for l in leaves:
            shapes.append(tuple(l.shape))
            offsets.append(off)
            n = 1
            for d in l.shape:
                n *= int(d)
            off += n
        return cls(treedef=treedef, shapes=tuple(shapes),
                   offsets=tuple(offsets), n_coords=off)

    def flatten(self, tree) -> jax.Array:
        """pytree -> (n_coords,) float32 buffer (the one flatten per round)."""
        leaves = jax.tree_util.tree_leaves(tree)
        return jnp.concatenate(
            [l.astype(jnp.float32).reshape(-1) for l in leaves])

    def unflatten(self, flat: jax.Array):
        """(>= n_coords,) buffer -> pytree of float32 leaves.

        Accepts padded buffers: only the leading ``n_coords`` entries are
        read, so sign decoders never need to slice off pack/tile padding.
        """
        leaves = []
        for shape, off in zip(self.shapes, self.offsets):
            n = 1
            for d in shape:
                n *= d
            leaves.append(jax.lax.dynamic_slice_in_dim(flat, off, n)
                          .reshape(shape))
        return jax.tree_util.tree_unflatten(self.treedef, leaves)


def tree_spec(tree) -> TreeSpec:
    return TreeSpec.from_tree(tree)


# ---------------------------------------------------------------------------
# sign bitpacking (pure-jnp reference path, little-endian bit order; the
# Pallas kernel in kernels/zsign produces the identical byte stream)
# ---------------------------------------------------------------------------

def pack_bool(bits: jax.Array) -> jax.Array:
    """bool (flat, len % 8 == 0) -> uint8 bitfield of len/8.

    THE little-endian pack every sign path shares: element 8i+j lands in bit
    j of byte i. The Pallas kernels keep a shape-local copy of these three
    lines (kernels/zsign ``_pack_bits_u8``) — bit-exactness between the two
    is pinned by the encode-equivalence tests.
    """
    b = bits.astype(jnp.uint8).reshape(-1, 8)
    weights = (jnp.uint8(1) << jnp.arange(8, dtype=jnp.uint8))
    return jnp.sum(b * weights, axis=-1, dtype=jnp.uint8)


def pack_signs(signs_i8: jax.Array) -> jax.Array:
    """int8 {-1,+1} (flat, len % 8 == 0) -> uint8 bitfield of len/8."""
    return pack_bool(signs_i8 > 0)


def unpack_signs(packed: jax.Array) -> jax.Array:
    """uint8 bitfield -> int8 {-1,+1} of len*8."""
    weights = (jnp.uint8(1) << jnp.arange(8, dtype=jnp.uint8))
    bits = (packed[:, None] & weights) > 0
    return jnp.where(bits, jnp.int8(1), jnp.int8(-1)).reshape(-1)


def pad_to(x: jax.Array, mult: int) -> jax.Array:
    r = (-x.shape[0]) % mult
    return jnp.pad(x, (0, r)) if r else x


def pack_flat(flat: jax.Array) -> jax.Array:
    """(d,) f32 -> bitpacked uint8 of ceil(d/8): bit = flat[i] >= 0.

    Zero-padded tail packs as +1 bits; harmless because ``TreeSpec.unflatten``
    never reads past n_coords.
    """
    y = pad_to(flat, 8)
    return pack_signs(jnp.where(y >= 0, jnp.int8(1), jnp.int8(-1)))


# Clients per accumulation block. MUST match kernels/zsign/zsign.CLIENT_BLK:
# the jnp fallback below accumulates in the same blocked client order as the
# Pallas sign_reduce kernel, so the CPU path and the TPU kernel produce
# bit-identical f32 sums for ANY per-client weights (not just 0/1 masks).
SIGN_REDUCE_CLIENT_BLK = 8


def _bit_transpose_blocks(pm: jax.Array, n_blocks: int,
                          n_bytes: int) -> jax.Array:
    """(n_blocks*8, n_bytes) u8 -> (n_blocks, 8, n_bytes) u8 bitplanes.

    Three butterfly stages (Hacker's Delight 7-3, vectorized over all bytes)
    transpose each block's 8x8 bit tile: plane k's byte j holds, in bit i,
    bit k of client i's byte j — i.e. one byte now carries 8 CLIENTS' bits
    for a single coordinate. ~24 u8 passes over the wire bytes, no
    per-coordinate expansion.
    """
    u8 = jnp.uint8
    x = pm.reshape(n_blocks, 2, 2, 2, n_bytes)
    t, b = x[:, 0], x[:, 1]
    x = jnp.stack([(t & u8(0x0F)) | ((b & u8(0x0F)) << 4),
                   ((t & u8(0xF0)) >> 4) | (b & u8(0xF0))], axis=1)
    t, b = x[:, :, 0], x[:, :, 1]
    x = jnp.stack([(t & u8(0x33)) | ((b & u8(0x33)) << 2),
                   ((t & u8(0xCC)) >> 2) | (b & u8(0xCC))], axis=2)
    t, b = x[:, :, :, 0], x[:, :, :, 1]
    x = jnp.stack([(t & u8(0x55)) | ((b & u8(0x55)) << 1),
                   ((t & u8(0xAA)) >> 1) | (b & u8(0xAA))], axis=3)
    return x.reshape(n_blocks, 8, n_bytes)


def _block_luts(wb: jax.Array) -> jax.Array:
    """(n_blocks, blk) f32 weight blocks -> (n_blocks, 256) weighted-sign
    tables ``LUT[v] = sum_i (bit i of v ? +w_i : -w_i)`` — the in-block
    8-client reduce, performed once per block at table-build time in client
    order (the order the Pallas kernel and the dense oracle share)."""
    v = jnp.arange(256, dtype=jnp.uint8)
    vbits = ((v[:, None] >> jnp.arange(8, dtype=jnp.uint8))
             & jnp.uint8(1)) > 0                            # (256, 8)
    return jnp.sum(jnp.where(vbits[None], wb[:, None, :], -wb[:, None, :]),
                   axis=-1)                                 # (n_blocks, 256)


def unpack_sum(packed: jax.Array, weights: jax.Array,
               acc: "jax.Array | SignFoldAcc | None" = None) -> jax.Array:
    """(n_clients, n_bytes) u8, (n_clients,) f32 -> (8*n_bytes,) weighted sum
    of the +/-1 signs — the server side of the 1-bit all-gather.

    LUT over transposed bitplanes: each block of 8 clients is bit-TRANSPOSED
    (``_bit_transpose_blocks``) so one byte holds the block's 8 sign bits for
    a single coordinate, then a per-block 256-entry table
    ``LUT[v] = sum_i (bit i of v ? +w_i : -w_i)`` turns the weighted
    8-client reduce into one cache-resident gather per coordinate. The dense
    (n_clients, d) fp32 sign matrix (32 bits/coord/client) that the
    pre-fused server decode materialized never exists — the fp32 working set
    is the output-sized accumulator only (~5-10x faster than the dense path
    on CPU at n_clients >= 32; see BENCH_kernels.json / BENCH_round.json). Dead clients
    (weight 0) contribute exactly 0.

    ``acc`` is the partial-accumulator FOLD hook for the streaming cohort
    driver, in one of two forms:

      * an (8*n_bytes,) f32 running sum from previous client shards,
        continued as the flat left fold ``((acc + b_0) + b_1) + ...`` over
        this call's client blocks. Bit-identical to one call over the
        concatenated clients whenever (a) the weights are a 0/1 mask
        (integer sums — exact under any association) or (b) every shard is
        a multiple of SIGN_REDUCE_CLIENT_BLK clients (identical block
        boundaries AND identical left-fold order, any fp32 weights), up to
        the sign of f32 zeros. Off-block shard sizes shift the 8-client
        block boundaries and therefore re-associate the fp32 sums.
      * a :class:`SignFoldAcc` (from :func:`sign_fold_init`): the
        shard-partition-INVARIANT fold. Sub-block client remainders are
        buffered as pending wire rows instead of closing a misaligned
        block, so the global 8-client block boundaries — and the exact
        fp32 addition order — match the single concatenated call for ANY
        shard partition and any fp32 weights. The return value is the
        updated SignFoldAcc; :func:`sign_fold_finalize` flushes the last
        partial block and yields the (8*n_bytes,) sum, bit-identical to
        the one-shot call (zero signs included).

    Accumulation order mirrors the Pallas ``sign_reduce`` kernel: clients
    are padded to SIGN_REDUCE_CLIENT_BLK with zero weight, the in-block
    8-element reduce happens at LUT build time in client order, and block
    partials are added sequentially — bit-exact vs the kernel for ANY fp32
    weights (verified in tests/test_sign_reduce.py), exact vs any order for
    0/1 masks (integer sums), and within 1 ulp/client of the legacy dense
    path (``unpack_sum_dense``).
    """
    if isinstance(acc, SignFoldAcc):
        return _sign_fold_step(packed, weights, acc)
    n, n_bytes = packed.shape
    blk = SIGN_REDUCE_CLIENT_BLK
    cpad = (-n) % blk
    if cpad:
        packed = jnp.pad(packed, ((0, cpad), (0, 0)))
    w = weights.astype(jnp.float32)
    if cpad:
        w = jnp.pad(w, (0, cpad))
    n_blocks = (n + cpad) // blk
    planes = _bit_transpose_blocks(packed, n_blocks, n_bytes)
    lut = _block_luts(w.reshape(n_blocks, blk))             # (n_blocks, 256)
    if acc is None:
        a = jnp.take(lut[0], planes[0].astype(jnp.int32), axis=0)  # (8, nb)
        start = 1
    else:
        # resume the left fold from the carried partial sum (inverse of the
        # output layout below: coordinate byte*8 + k lives at [k, byte])
        a = jnp.swapaxes(acc.reshape(n_bytes, 8), 0, 1)
        start = 0
    for b in range(start, n_blocks):
        a = a + jnp.take(lut[b], planes[b].astype(jnp.int32), axis=0)
    # a[k, byte] is the weighted sum for coordinate byte*8 + k
    return jnp.swapaxes(a, 0, 1).reshape(-1)


class SignFoldAcc(NamedTuple):
    """Shard-partition-invariant carry for the fp32-weighted sign fold.

    The flat ``acc`` fold of :func:`unpack_sum` closes an 8-client LUT block
    at every shard boundary, so a shard size that is not a multiple of
    SIGN_REDUCE_CLIENT_BLK shifts the block boundaries and re-associates the
    fp32 additions — the historical "bit-identical only at shard % 8 == 0"
    caveat. This carry removes the caveat structurally: clients that do not
    fill a block are PARKED as pending wire rows (bytes + weights) and the
    block is only closed — in global client order — once 8 rows exist, so
    the fold replays the exact addition sequence of the single concatenated
    call no matter how the client stream is partitioned.

    Bit-exactness bookkeeping: ``sums`` starts at -0.0 (the IEEE-754
    additive identity that preserves the bit pattern of every float,
    including +/-0.0), and deferred / absent blocks contribute a -0.0 term
    instead of being skipped, so every closed block enters the sum exactly
    once and in the same order as the one-shot call — the finalized result
    is bit-identical, zero signs included.

    Fields:
      sums        (8, n_bytes) f32 — closed-block partial sums in the
                  bit-transposed layout (coordinate byte*8 + k at [k, byte])
      pend_bytes  (SIGN_REDUCE_CLIENT_BLK, n_bytes) u8 — buffered wire rows
                  of the open block; rows >= pend_n are zero
      pend_w      (SIGN_REDUCE_CLIENT_BLK,) f32 — their weights (same rule)
      pend_n      () int32 — number of valid pending rows, 0..7

    A NamedTuple, hence a pytree: it rides through ``lax.scan`` carries,
    ``jax.jit`` boundaries and ``shard_map`` bodies unchanged. It must be
    finalized (:func:`sign_fold_finalize`) BEFORE any cross-device psum —
    pending rows are positional, not additive.
    """
    sums: jax.Array
    pend_bytes: jax.Array
    pend_w: jax.Array
    pend_n: jax.Array


def sign_fold_init(n_bytes: int) -> SignFoldAcc:
    """Fresh partition-invariant fold carry for an (.., n_bytes) wire row."""
    blk = SIGN_REDUCE_CLIENT_BLK
    return SignFoldAcc(
        sums=jnp.full((8, n_bytes), -0.0, jnp.float32),
        pend_bytes=jnp.zeros((blk, n_bytes), jnp.uint8),
        pend_w=jnp.zeros((blk,), jnp.float32),
        pend_n=jnp.zeros((), jnp.int32))


def _sign_fold_step(packed: jax.Array, weights: jax.Array,
                    acc: SignFoldAcc) -> SignFoldAcc:
    """Fold one shard of (k, n_bytes) wire rows into the carry.

    The pending rows (0..7 of them) are placed at the head of a zero
    buffer, the shard's rows behind them at the traced offset ``pend_n``;
    every COMPLETE 8-row block of the buffer is closed in order (incomplete
    trailing rows add a bit-preserving -0.0 instead), and the remainder is
    sliced back out as the new pending block. The buffer is sized so
    neither the dynamic_update_slice nor the trailing dynamic_slice can
    clamp: B = ((7 + k) // 8 + 1) * 8 >= pend_n + k + 1 and >= s + 8 for
    the remainder start s = ((pend_n + k) // 8) * 8.
    """
    k, n_bytes = packed.shape
    blk = SIGN_REDUCE_CLIENT_BLK
    n_blocks = (blk - 1 + k) // blk + 1
    buf_rows = n_blocks * blk
    buf = jnp.zeros((buf_rows, n_bytes), jnp.uint8).at[:blk].set(
        acc.pend_bytes)
    wbuf = jnp.zeros((buf_rows,), jnp.float32).at[:blk].set(acc.pend_w)
    buf = jax.lax.dynamic_update_slice(buf, packed, (acc.pend_n, 0))
    wbuf = jax.lax.dynamic_update_slice(
        wbuf, weights.astype(jnp.float32), (acc.pend_n,))
    total = acc.pend_n + k
    planes = _bit_transpose_blocks(buf, n_blocks, n_bytes)
    lut = _block_luts(wbuf.reshape(n_blocks, blk))
    neg0 = jnp.full((8, n_bytes), -0.0, jnp.float32)
    a = acc.sums
    for b in range(n_blocks):
        contrib = jnp.take(lut[b], planes[b].astype(jnp.int32), axis=0)
        a = a + jnp.where((b + 1) * blk <= total, contrib, neg0)
    start = (total // blk) * blk
    return SignFoldAcc(
        sums=a,
        pend_bytes=jax.lax.dynamic_slice(buf, (start, 0), (blk, n_bytes)),
        pend_w=jax.lax.dynamic_slice(wbuf, (start,), (blk,)),
        pend_n=total % blk)


def sign_fold_finalize(acc: SignFoldAcc) -> jax.Array:
    """Close the open block and return the (8*n_bytes,) weighted sign sum —
    bit-identical to one :func:`unpack_sum` call over the concatenated
    clients (whose trailing partial block is zero-padded exactly like the
    pending buffer). A carry with no pending rows adds -0.0, a bitwise
    no-op."""
    n_bytes = acc.pend_bytes.shape[1]
    planes = _bit_transpose_blocks(acc.pend_bytes, 1, n_bytes)
    lut = _block_luts(acc.pend_w.reshape(1, -1))
    contrib = jnp.take(lut[0], planes[0].astype(jnp.int32), axis=0)
    neg0 = jnp.full((8, n_bytes), -0.0, jnp.float32)
    a = acc.sums + jnp.where(acc.pend_n > 0, contrib, neg0)
    return jnp.swapaxes(a, 0, 1).reshape(-1)


def check_mask_membership(mask: jax.Array) -> None:
    """Runtime assertion of the 0/1 membership contract (debug-wire mode).

    The popcount/vote paths are only correct for masks that are EXACTLY 0.0
    or 1.0 per entry — the static ``weights_are_mask`` guarantee. This is the
    dynamic counterpart, inserted when ``RoundContext(debug_wire=True)`` (or
    ``REPRO_DEBUG_WIRE=1``) is set: a ``checkify.check`` over the traced mask
    values. Called eagerly it raises immediately on violation; under ``jit``
    the caller must functionalize the check, i.e. wrap the jitted step as
    ``err, out = checkify.checkify(jax.jit(step))(...); err.throw()`` — the
    train/dryrun launchers and the CI attacks job do exactly that. A bare
    ``jax.jit`` around a debug-wire step fails at trace time with checkify's
    "not functionalized" error, which is intentional: debug mode refuses to
    run unchecked.
    """
    m = jnp.asarray(mask)
    ok = jnp.all((m == 0.0) | (m == 1.0))
    checkify.check(ok, "debug_wire: mask violates the 0/1 membership "
                       "contract required by the popcount/vote paths "
                       "(weights_are_mask) — found fractional or negative "
                       "weights. Use weights_are_mask=False (LUT path) for "
                       "weighted aggregation.")


def unpack_sum_mask(packed: jax.Array, mask: jax.Array,
                    acc: jax.Array | None = None, *,
                    debug: bool = False) -> jax.Array:
    """(n_clients, n_bytes) u8, (n_clients,) 0/1 mask -> (8*n_bytes,) f32
    masked sum of the +/-1 signs — the popcount fast path.

    For membership weights the weighted sum collapses to an integer bit
    count: sum_live(2b - 1) = 2*count - n_live. The count is computed
    entirely in the uint8 wire domain: dead clients' bytes are zeroed, each
    block of 8 clients is bit-TRANSPOSED in three butterfly stages (Hacker's
    Delight 7-3, vectorized over all bytes) so one byte holds 8 clients'
    bits for a single coordinate, then ``lax.population_count`` + a tiny
    cross-block add yield the per-coordinate count. ~24 u8 passes over the
    wire bytes total — no per-coordinate expansion to int8/fp32 at all
    (~9x over the dense path on CPU at n_clients = 32, on par with the
    weighted LUT gather of ``unpack_sum``; see BENCH_kernels.json). Exact
    by construction (integer counts), so it is bit-identical to
    ``unpack_sum``, ``unpack_sum_dense`` and the Pallas kernel for any 0/1
    mask.

    The mask is treated as MEMBERSHIP (w > 0 participates); fractional
    weights must use :func:`unpack_sum`. ``acc`` folds a running partial sum
    from previous client shards (streaming cohort driver); because every
    term is a small integer, the shard-by-shard fold is bit-identical to
    one call over the concatenated clients for ANY shard size.

    Because the membership contract cannot be
    checked on traced values, dispatch here is gated on a STATIC guarantee
    plumbed from whoever constructs the mask: the round engine's
    ``build_round_step(weights_are_mask=True)`` (set by the train/dryrun
    launchers, whose participation sampler emits exact 0/1) flips the
    sign-family compressors' flag and ``compression.sign_reduce`` then
    routes its jnp backend through this popcount path. Weighted calls (EF
    mask * scale, data-size weights) keep the LUT path. ``debug=True`` adds
    the dynamic membership assertion (:func:`check_mask_membership`) on top
    of the static gate.
    """
    if debug:
        check_mask_membership(mask)
    bitsum = _mask_bit_count(packed, mask).astype(jnp.float32)
    out = 2.0 * bitsum - jnp.sum(mask)
    return out if acc is None else acc + out


def _mask_bit_count(packed: jax.Array, mask: jax.Array) -> jax.Array:
    """(n_clients, n_bytes) u8 + (n_clients,) 0/1 mask -> (8*n_bytes,)
    per-coordinate count of set bits across live clients (integer dtype).

    The shared popcount core of :func:`unpack_sum_mask` and
    :func:`vote_accumulator`. The cross-block accumulator stays uint8 only
    while EVERY physically settable bit fits: after zero-padding clients to
    the 8-row block boundary there are ``n + (-n) % 8`` block rows, and
    although the pad rows are zeroed today, the safe bound is the padded row
    count — u8 accumulation is used only when ``n + (-n) % 8 <= 255``
    (i.e. n <= 248), int32 otherwise. (The previous ``n <= 255`` bound
    leaned on the pad rows staying zero; this one is safe for any bit the
    buffer can hold. Regression-pinned at the boundary in
    tests/test_sign_reduce.py.)
    """
    n, n_bytes = packed.shape
    pm = packed * (mask > 0).astype(jnp.uint8)[:, None]
    cpad = (-n) % 8
    if cpad:
        pm = jnp.pad(pm, ((0, cpad), (0, 0)))
    n_blocks = (n + cpad) // 8
    planes = _bit_transpose_blocks(pm, n_blocks, n_bytes)
    cnt = jax.lax.population_count(planes)          # (blocks, 8, n_bytes) u8
    acc_dtype = jnp.uint8 if n + cpad <= 255 else jnp.int32
    c = jnp.sum(cnt, axis=0, dtype=acc_dtype) if n_blocks > 1 else cnt[0]
    # c[k, byte] counts set bit-k across live clients; coord = byte*8 + k
    return jnp.swapaxes(c, 0, 1).reshape(-1)


#: Robust sign-aggregation modes decodable from the (signed_count, n_live)
#: vote pair — see :func:`vote_accumulator` / :func:`vote_decode`.
VOTE_AGG_MODES = ("mean", "vote", "trimmed", "median")


def vote_accumulator(packed: jax.Array, mask: jax.Array,
                     acc: jax.Array | None = None, *,
                     debug: bool = False) -> jax.Array:
    """(n_clients, n_bytes) u8 + (n_clients,) 0/1 mask -> (2, 8*n_bytes)
    int32 VOTE PAIR: row 0 the per-coordinate signed vote count
    ``s = sum_live sign_i`` (= 2*count - n_live), row 1 the live count
    ``n_live`` (broadcast per coordinate).

    The integer sufficient statistic for EVERY robust sign aggregate: for
    +/-1 votes, mean, majority vote, coordinate-wise trimmed(f) mean, and
    coordinate-wise median are all closed-form post-processings of
    ``(s, n_live)`` — see :func:`vote_decode`. Because both rows are plain
    integer SUMS over clients, the pair

      * folds additively across streamed client shards (``acc`` carries the
        running pair; bit-exact for any shard size — integer arithmetic),
      * crosses devices in the SAME single ``lax.psum`` as the mean path
        (:func:`psum_accumulator` on the int32 pair, O(2d) on the wire),
      * never inflates to an (n_clients, d) matrix — same ~24 u8 passes as
        :func:`unpack_sum_mask` plus one subtract.

    Requires the 0/1 membership contract (weights_are_mask); fractional
    weights have no integer vote-count semantics. ``debug=True`` adds the
    dynamic assertion of that contract.
    """
    if debug:
        check_mask_membership(mask)
    bitsum = _mask_bit_count(packed, mask).astype(jnp.int32)
    n_live = jnp.sum(mask).astype(jnp.int32)
    pair = jnp.stack([2 * bitsum - n_live,
                      jnp.broadcast_to(n_live, bitsum.shape)])
    return pair if acc is None else acc + pair


def vote_decode(pair: jax.Array, agg: str, trim_f: int = 0) -> jax.Array:
    """(2, d) int32 vote pair -> (d,) f32 robust aggregate in [-1, 1].

    Closed forms from ``s = pair[0]`` (signed count) and ``n = pair[1]``
    (live count), with ``c = (s + n) / 2`` the number of +1 votes (always
    integral: s and n have equal parity, preserved by additive folds):

      mean        s / n                      (the plain masked sign mean)
      vote        sign(s)                    (coordinate majority; 0 at tie)
      trimmed(f)  drop the f largest and f smallest votes, average the
                  m = n - 2f survivors. Sorting +/-1 votes puts the -1s
                  first, so the survivors keep plus' = clip(c - f, 0, m)
                  of the +1 votes: (2*plus' - m) / m. When a round is
                  over-trimmed (n <= 2f) the trim level degrades to the
                  deepest possible, f_eff = (n - 1) // 2 — i.e. the median.
      median      trimmed with runtime f = (n - 1) // 2 — for +/-1 votes
                  this equals sign(s) for odd n and the 0-at-tie midpoint
                  rule for even n (identical to vote in value; kept as a
                  separate mode for the standard robust-aggregation name).

    trimmed(0) is EXACTLY the mean. All-dead coordinates (n_live = 0)
    decode to 0 in every mode. Everything here is integer-derived, so the
    result is bit-identical to the dense-matrix oracle
    (tests/test_robust_agg.py).
    """
    if agg not in VOTE_AGG_MODES:
        raise ValueError(f"unknown vote agg mode {agg!r}; expected one of "
                         f"{VOTE_AGG_MODES}")
    s = pair[0].astype(jnp.float32)
    n = pair[1].astype(jnp.float32)
    if agg == "mean":
        return s / jnp.maximum(n, 1.0)
    if agg == "vote":
        return jnp.sign(s)
    f_max = jnp.floor((jnp.maximum(n, 1.0) - 1.0) / 2.0)
    f = f_max if agg == "median" else jnp.minimum(jnp.float32(trim_f), f_max)
    c = (s + n) * 0.5
    m = jnp.maximum(n - 2.0 * f, 1.0)
    plus = jnp.clip(c - f, 0.0, m)
    return jnp.where(n > 0, (2.0 * plus - m) / m, 0.0)


def dense_masked_sum(payload: jax.Array, weights: jax.Array,
                     acc: jax.Array | None = None) -> jax.Array:
    """Server side of the dense fp32 uplink: one weighted einsum.

    (n_clients, d) payload + (n_clients,) weights -> (d,) f32 weighted sum —
    the aggregation every dense-wire codec (identity, qsgd, dp-over-dense)
    shares. Dead clients (weight 0) contribute exactly 0. ``acc`` carries a
    running partial sum across client shards (the streaming driver's dense
    fallback: the carry stays one (d,) buffer).
    """
    out = jnp.einsum("nd,n->d", payload.astype(jnp.float32), weights)
    return out if acc is None else acc + out


def scatter_sum_coo(values: jax.Array, indices: jax.Array,
                    weights: jax.Array, n_coords: int,
                    acc: jax.Array | None = None) -> jax.Array:
    """Server side of the sparse COO uplink: weighted scatter-add.

    (n_clients, k) f32 values + (n_clients, k) int32 indices +
    (n_clients,) f32 weights -> (n_coords,) f32 weighted sum. Dead clients
    (weight 0) contribute exactly 0; duplicate indices across clients
    accumulate. The compressed-domain counterpart of ``unpack_sum`` for the
    "sparse_coo" wire layout — the dense (n_clients, d) scatter surface
    never exists, only the output-sized accumulator. ``acc`` scatter-adds
    into a carried (n_coords,) partial sum instead of a fresh zero buffer
    (streaming cohort fold).
    """
    vals = (values * weights[:, None]).reshape(-1)
    idx = indices.reshape(-1)
    base = jnp.zeros((n_coords,), jnp.float32) if acc is None else acc
    return base.at[idx].add(vals)


def unpack_sum_dense(packed: jax.Array, weights: jax.Array,
                     acc: jax.Array | None = None) -> jax.Array:
    """Legacy dense-matrix weighted sign sum (pre-fused server decode).

    Materializes the full (n_clients, d) fp32 sign matrix before the einsum
    — a 32x working-set blowup over the wire bytes. Kept ONLY as the oracle
    for the sign-reduce equivalence tests and as the "old" side of the
    ``fed_round_step`` benchmark; no production path calls it. ``acc``
    mirrors the fold hook of :func:`unpack_sum` so the oracle covers the
    streaming fold tests too.
    """
    signs = jax.vmap(unpack_signs)(packed).astype(jnp.float32)
    out = jnp.einsum("nd,n->d", signs, weights)
    return out if acc is None else acc + out


def psum_accumulator(acc: jax.Array, axis_name: str) -> jax.Array:
    """Cross-device reduce of a wire ACCUMULATOR over a named mesh axis.

    Every codec's ``aggregate`` is a linear SUM over its client axis,
    so per-device partial accumulators combine by plain addition — one
    ``lax.psum`` of the (d,)-sized (or (d_pad,)-sized) f32 buffer — or, for
    the robust ``agg=vote|trimmed|median`` modes, of the (2, d_pad) int32
    vote pair (:func:`vote_accumulator`) — is the
    entire cross-device protocol of a streamed multi-device round. Per
    device that is O(d) fp32 on the interconnect, independent of cohort
    size: the compressed-domain analogue of the server all-reduce, and the
    ONLY collective the multi-device cohort engine is allowed to emit
    (jaxpr-pinned in tests/test_cohort_stream.py). Integer-valued sign sums
    (0/1 masks) stay exact under the psum's reduction order, which is what
    makes device count a bit-invariant choice there.
    """
    return jax.lax.psum(acc, axis_name)
