"""RoundContext: the one typed knob-bundle a federated round runs under.

Before this module existed, every deployment policy knob travelled as its own
positional/keyword argument through three layers (compressor constructor ->
``fedavg.build_round_step`` -> train/dryrun CLIs), and each sign-family
compressor class re-resolved "auto" backends for itself. ``RoundContext``
makes the policy a single frozen value:

  * ``agg_backend`` / ``encode_backend`` — backend policy for the server
    sign-reduce and the client fused encode. ``None`` means "keep whatever
    the pipeline stage was built with" (e.g. ``zsign_packed`` pins pallas);
    an explicit string overrides every sign stage in the pipeline.
  * ``weights_are_mask`` — the caller's STATIC guarantee that aggregation
    weights are exact 0/1 participation masks (unlocks the popcount
    sign-reduce specialization; see wire.unpack_sum_mask).
  * ``legacy_client_path`` — restore the pre-fused client step (scan over E
    even at E == 1 + update/subtract round-trip); benchmark baseline only.
  * ``dynamic_sigma`` — thread the server state's traced sigma (Plateau
    controller) into the codec instead of its static config value.
  * ``donate_state`` — whether drivers donate the server state into the
    jitted round step (in-place params/opt/residual update).
  * ``cohort`` — how the round driver walks the cohort: one vmap over all
    clients, or the streaming shard scan that folds each shard's payloads
    into a running wire accumulator (see :class:`CohortPolicy`).

``resolve_backend`` is THE one place an "auto" backend becomes a concrete
one: the Pallas kernels on TPU, the fused jnp paths elsewhere. Everything
that dispatches a kernel (compression.sign_reduce, the sign codec's encode)
calls it, so a deployment can reason about backend selection by reading one
function.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax

#: aggregation backends for the sign-family weighted reduce
AGG_BACKENDS = ("auto", "jnp", "pallas", "dense")

#: client-encode backends for the sign family ("reference" = dense draw)
ENCODE_BACKENDS = ("auto", "jnp", "pallas", "reference")

#: cohort execution modes for the round driver (see CohortPolicy)
COHORT_MODES = ("auto", "vmap", "stream")

#: auto-gate threshold for the streaming cohort executor, in client-coordinate
#: elements (total_clients * n_coords). Below it one vmap over the whole
#: cohort is both faster (lax.scan costs ~30-80 ms/round of loop overhead on
#: XLA CPU) and small enough to hold; at or above it the streaming driver's
#: O(shard * d/8) wire working set wins. 2**24 elements ~ 64 MB of dense f32
#: client state — roughly where the full-cohort vmap stops being free.
STREAM_AUTO_MIN_ELEMS = 1 << 24

#: default clients per shard when a streaming policy does not pin one
STREAM_DEFAULT_SHARD = 64

_VALID = {"agg": AGG_BACKENDS, "encode": ENCODE_BACKENDS}


def resolve_backend(kind: str, backend: str) -> str:
    """Resolve an ``auto`` backend to a concrete one — the single policy
    point for ``auto|jnp|pallas|reference|dense``.

    ``kind`` is "agg" (server sign-reduce: auto|jnp|pallas|dense) or
    "encode" (client fused encode: auto|jnp|pallas|reference). "auto" picks
    the Pallas kernel on TPU and the fused jnp path everywhere else; any
    other name must be a member of the kind's backend tuple.
    """
    valid = _VALID[kind]
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend not in valid:
        raise ValueError(f"unknown {kind} backend {backend!r}; "
                         f"expected one of {valid}")
    return backend


@dataclasses.dataclass(frozen=True)
class CohortPolicy:
    """Parsed form of ``RoundContext.cohort`` — how the round driver walks
    the cohort.

      mode="vmap"    one vmap over all ``client_groups * n_clients`` clients
                     (plus the legacy sequential-group scan when groups > 1).
      mode="stream"  shard the flat cohort into ``shard``-client slices and
                     ``lax.scan`` them through the fused encode, folding each
                     shard's payload stack into ONE running wire accumulator
                     (compression.Pipeline.aggregate(..., acc=...)). Peak
                     memory O(d) model + O(shard * d/8) wire, any cohort size.
      mode="auto"    stream iff total_clients * n_coords >=
                     STREAM_AUTO_MIN_ELEMS (the small-run regression gate).

    ``shard == 0`` leaves the shard size to the engine
    (STREAM_DEFAULT_SHARD); a bare ``stream`` spec therefore still
    auto-gates back to vmap below the threshold, while an explicit
    ``stream(shard=K)`` FORCES streaming at exactly K clients per shard
    (the bit-identity tests rely on this). ``unroll`` is handed to the
    shard ``lax.scan`` to amortize loop overhead.
    """
    mode: str = "auto"
    shard: int = 0
    unroll: int = 1

    def __post_init__(self):
        if self.mode not in COHORT_MODES:
            raise ValueError(f"unknown cohort mode {self.mode!r}; expected "
                             f"one of {COHORT_MODES}")
        if self.shard < 0 or self.unroll < 1:
            raise ValueError(f"cohort policy needs shard >= 0 and "
                             f"unroll >= 1, got shard={self.shard} "
                             f"unroll={self.unroll}")
        if self.shard and self.mode != "stream":
            raise ValueError(f"shard={self.shard} only applies to "
                             f"cohort mode 'stream', not {self.mode!r}")

    @classmethod
    def parse(cls, spec: "str | CohortPolicy") -> "CohortPolicy":
        """``auto | vmap | stream | stream(shard=K[,unroll=U])`` -> policy."""
        if isinstance(spec, cls):
            return spec
        s = spec.strip()
        if "(" not in s:
            return cls(mode=s)
        if not s.endswith(")"):
            raise ValueError(f"malformed cohort spec {spec!r}")
        mode, args = s[:-1].split("(", 1)
        kw = {}
        for part in filter(None, (p.strip() for p in args.split(","))):
            if "=" not in part:
                raise ValueError(f"cohort argument {part!r} in {spec!r} "
                                 f"must be key=value")
            k, v = part.split("=", 1)
            if k.strip() not in ("shard", "unroll"):
                raise ValueError(f"unknown cohort argument {k.strip()!r} in "
                                 f"{spec!r}; expected shard= or unroll=")
            try:
                kw[k.strip()] = int(v.strip())
            except ValueError:
                raise ValueError(f"cohort argument {part!r} in {spec!r} "
                                 f"must be an integer") from None
        return cls(mode=mode.strip(), **kw)


@dataclasses.dataclass(frozen=True)
class RoundContext:
    """Frozen per-deployment policy for one federated round step.

    Constructed once by whoever owns the deployment decision (the train /
    dryrun CLIs, run_fed, a test) and handed to
    ``fedavg.build_round_step(loss_fn, compressor, cfg, ctx)``; the engine
    applies it to the compression pipeline via ``Pipeline.with_context`` and
    to its own client/aggregation paths. ``None`` backends defer to the
    pipeline stage's own config.
    """
    agg_backend: Optional[str] = None
    encode_backend: Optional[str] = None
    weights_are_mask: bool = False
    legacy_client_path: bool = False
    dynamic_sigma: bool = False
    donate_state: bool = True
    #: cohort execution policy for the round driver — a CohortPolicy spec
    #: string: "auto" | "vmap" | "stream" | "stream(shard=K[,unroll=U])"
    cohort: str = "auto"

    def __post_init__(self):
        # fail at construction, not at trace time inside the round step —
        # membership is owned by resolve_backend / CohortPolicy, reused here
        for kind, backend in (("agg", self.agg_backend),
                              ("encode", self.encode_backend)):
            if backend is not None:
                resolve_backend(kind, backend)
        CohortPolicy.parse(self.cohort)
