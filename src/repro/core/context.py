"""RoundContext: the one typed knob-bundle a federated round runs under.

Before this module existed, every deployment policy knob travelled as its own
positional/keyword argument through three layers (compressor constructor ->
``fedavg.build_round_step`` -> train/dryrun CLIs), and each sign-family
compressor class re-resolved "auto" backends for itself. ``RoundContext``
makes the policy a single frozen value:

  * ``agg_backend`` / ``encode_backend`` — backend policy for the server
    sign-reduce and the client fused encode. ``None`` means "keep whatever
    the pipeline stage was built with" (e.g. ``zsign_packed`` pins pallas);
    an explicit string overrides every sign stage in the pipeline.
  * ``weights_are_mask`` — the caller's STATIC guarantee that aggregation
    weights are exact 0/1 participation masks (unlocks the popcount
    sign-reduce specialization; see wire.unpack_sum_mask).
  * ``legacy_client_path`` — restore the pre-fused client step (scan over E
    even at E == 1 + update/subtract round-trip); benchmark baseline only.
  * ``dynamic_sigma`` — thread the server state's traced sigma (Plateau
    controller) into the codec instead of its static config value.
  * ``donate_state`` — whether drivers donate the server state into the
    jitted round step (in-place params/opt/residual update).

``resolve_backend`` is THE one place an "auto" backend becomes a concrete
one: the Pallas kernels on TPU, the fused jnp paths elsewhere. Everything
that dispatches a kernel (compression.sign_reduce, the sign codec's encode)
calls it, so a deployment can reason about backend selection by reading one
function.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax

#: aggregation backends for the sign-family weighted reduce
AGG_BACKENDS = ("auto", "jnp", "pallas", "dense")

#: client-encode backends for the sign family ("reference" = dense draw)
ENCODE_BACKENDS = ("auto", "jnp", "pallas", "reference")

_VALID = {"agg": AGG_BACKENDS, "encode": ENCODE_BACKENDS}


def resolve_backend(kind: str, backend: str) -> str:
    """Resolve an ``auto`` backend to a concrete one — the single policy
    point for ``auto|jnp|pallas|reference|dense``.

    ``kind`` is "agg" (server sign-reduce: auto|jnp|pallas|dense) or
    "encode" (client fused encode: auto|jnp|pallas|reference). "auto" picks
    the Pallas kernel on TPU and the fused jnp path everywhere else; any
    other name must be a member of the kind's backend tuple.
    """
    valid = _VALID[kind]
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend not in valid:
        raise ValueError(f"unknown {kind} backend {backend!r}; "
                         f"expected one of {valid}")
    return backend


@dataclasses.dataclass(frozen=True)
class RoundContext:
    """Frozen per-deployment policy for one federated round step.

    Constructed once by whoever owns the deployment decision (the train /
    dryrun CLIs, run_fed, a test) and handed to
    ``fedavg.build_round_step(loss_fn, compressor, cfg, ctx)``; the engine
    applies it to the compression pipeline via ``Pipeline.with_context`` and
    to its own client/aggregation paths. ``None`` backends defer to the
    pipeline stage's own config.
    """
    agg_backend: Optional[str] = None
    encode_backend: Optional[str] = None
    weights_are_mask: bool = False
    legacy_client_path: bool = False
    dynamic_sigma: bool = False
    donate_state: bool = True

    def __post_init__(self):
        # fail at construction, not at trace time inside the round step —
        # membership is owned by resolve_backend, reused here
        for kind, backend in (("agg", self.agg_backend),
                              ("encode", self.encode_backend)):
            if backend is not None:
                resolve_backend(kind, backend)
