"""RoundContext: the one typed knob-bundle a federated round runs under.

Before this module existed, every deployment policy knob travelled as its own
positional/keyword argument through three layers (compressor constructor ->
``fedavg.build_round_step`` -> train/dryrun CLIs), and each sign-family
compressor class re-resolved "auto" backends for itself. ``RoundContext``
makes the policy a single frozen value:

  * ``agg_backend`` / ``encode_backend`` — backend policy for the server
    sign-reduce and the client fused encode. ``None`` means "keep whatever
    the pipeline stage was built with" (e.g. ``zsign_packed`` pins pallas);
    an explicit string overrides every sign stage in the pipeline.
  * ``weights_are_mask`` — the caller's STATIC guarantee that aggregation
    weights are exact 0/1 participation masks (unlocks the popcount
    sign-reduce specialization; see wire.unpack_sum_mask).
  * ``legacy_client_path`` — restore the pre-fused client step (scan over E
    even at E == 1 + update/subtract round-trip); benchmark baseline only.
  * ``dynamic_sigma`` — thread the server state's traced sigma (Plateau
    controller) into the codec instead of its static config value.
  * ``donate_state`` — whether drivers donate the server state into the
    jitted round step (in-place params/opt/residual update).
  * ``cohort`` — how the round driver walks the cohort: one vmap over all
    clients, or the streaming shard scan that folds each shard's payloads
    into a running wire accumulator (see :class:`CohortPolicy`).
  * ``debug_wire`` — runtime (checkify) verification of the 0/1-mask
    membership contract on every popcount/vote reduce; defaults from the
    ``REPRO_DEBUG_WIRE`` env var.
  * ``adversary`` — wire-level fault-injection policy (fed/adversary.py
    spec string) applied by the round driver: sign-flip / byte-corruption /
    colluding cohorts / mid-round dropout on a deterministic schedule.

``resolve_backend`` is THE one place an "auto" backend becomes a concrete
one: the Pallas kernels on TPU, the fused jnp paths elsewhere. Everything
that dispatches a kernel (compression.sign_reduce, the sign codec's encode)
calls it, so a deployment can reason about backend selection by reading one
function.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Optional

import jax

#: aggregation backends for the sign-family weighted reduce
AGG_BACKENDS = ("auto", "jnp", "pallas", "dense")

#: client-encode backends for the sign family ("reference" = dense draw)
ENCODE_BACKENDS = ("auto", "jnp", "pallas", "reference")

#: cohort execution modes for the round driver (see CohortPolicy)
COHORT_MODES = ("auto", "vmap", "stream")

#: shard-feeding modes for the streaming plan: "device" keeps the whole
#: cohort's batch/mask/state on device and scans it; "host" drives a
#: double-buffered host loop (fedavg.iter_shards + async jax.device_put of
#: shard t+1 while shard t computes) for cohorts whose mask/key/weight
#: tensors exceed device memory. A feed="host" round step is a Python loop —
#: it must NOT be wrapped in jax.jit.
COHORT_FEEDS = ("device", "host")

#: auto-gate threshold for the streaming cohort executor, in client-coordinate
#: elements (total_clients * n_coords). MEASURED on 1-core XLA CPU, jax
#: 0.4.37 (PR 7, jitted round step, median of 5-7):
#:
#:   * shard lax.scan loop overhead is ~0.1-0.2 ms per scanned shard, NOT
#:     the milliseconds the pre-PR-7 carry-over guessed: a 64-step
#:     stream(shard=4) round over 256 clients at d=1024 runs in 10.0 ms
#:     total, vs 14.4 ms for 16 steps of shard=16 (compute dominates).
#:   * unpacked sign wires: the two plans are within ~5% below the gate
#:     (n=256, d=1024: vmap 14.6 ms vs stream(shard=8) 15.4 ms; ef|zsign
#:     0.64 vs 0.65 ms) — vmap is kept there for its scan-free jaxpr, not
#:     for a large win.
#:   * zsign_packed: historically streaming won at EVERY size because the
#:     default pallas batching rule made the vmapped fused packed encode
#:     superlinear in the vmapped width (d=1024: 1.15 ms at n=16 -> 357 ms
#:     at n=256). PR 9 fixed that lowering (custom_vmap dual rule in
#:     kernels/zsign/ops.py, ~60 us/client flat at n=16..256), so the vmap
#:     plan is usable for packed wires below the gate too.
#:
#: At or above 2**24 elements (~64 MB of dense f32 client gradients) the
#: streaming plan's O(shard * d) working set is required regardless of
#: speed, so the gate stays at the memory bound rather than chasing the
#: wire-format-dependent crossover below it.
STREAM_AUTO_MIN_ELEMS = 1 << 24

#: default clients per shard when a streaming policy does not pin one and
#: shard auto-tuning has nothing to go on (n_coords == 0)
STREAM_DEFAULT_SHARD = 64

#: sentinel for ``stream(shard=auto)`` — fedavg.auto_shard_size picks K from
#: the model coordinate count and STREAM_SHARD_BUDGET_BYTES
STREAM_SHARD_AUTO = -1

#: sentinel for ``stream(devices=auto)`` — resolve_cohort expands it to
#: jax.device_count() at plan-resolution time
COHORT_DEVICES_AUTO = 0

#: per-device memory budget for one in-flight shard of client state. The
#: streaming engine's per-shard working set is ~one dense f32 gradient per
#: client plus the packed wire row (4*d + d/8 bytes per client), so the
#: auto-tuned shard size is budget // (4.125 * d), clamped to
#: [STREAM_SHARD_MIN, STREAM_SHARD_MAX] and rounded down to a multiple of
#: wire.SIGN_REDUCE_CLIENT_BLK to keep the fp32 fold bit-reproducible.
STREAM_SHARD_BUDGET_BYTES = 256 << 20

#: clamp bounds for the auto-tuned stream shard size (clients per shard)
STREAM_SHARD_MIN = 8
STREAM_SHARD_MAX = 512

#: round execution modes: the synchronous barrier (every live client's
#: payload lands before decode) or the async deadline round (see
#: RoundModePolicy)
ROUND_MODES = ("sync", "async")

#: buffered-staleness laws for async rounds: "none" drops late payloads,
#: "poly" down-weights a payload arriving s rounds late by (1+s)^-a,
#: "cutoff" keeps full weight up to s_max rounds late then drops
STALENESS_LAWS = ("none", "poly", "cutoff")

_VALID = {"agg": AGG_BACKENDS, "encode": ENCODE_BACKENDS}


def _split_top(args: str) -> list:
    """Split a spec argument list on TOP-LEVEL commas only, so nested
    parenthesized values — ``staleness=poly(0.5)`` — survive intact."""
    parts, cur, depth = [], [], 0
    for ch in args:
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
            continue
        depth += (ch == "(") - (ch == ")")
        if depth < 0:
            raise ValueError(f"unbalanced parentheses in {args!r}")
        cur.append(ch)
    if depth != 0:
        raise ValueError(f"unbalanced parentheses in {args!r}")
    parts.append("".join(cur))
    return [p.strip() for p in parts if p.strip()]


def resolve_backend(kind: str, backend: str) -> str:
    """Resolve an ``auto`` backend to a concrete one — the single policy
    point for ``auto|jnp|pallas|reference|dense``.

    ``kind`` is "agg" (server sign-reduce: auto|jnp|pallas|dense) or
    "encode" (client fused encode: auto|jnp|pallas|reference). "auto" picks
    the Pallas kernel on TPU and the fused jnp path everywhere else; any
    other name must be a member of the kind's backend tuple.
    """
    valid = _VALID[kind]
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend not in valid:
        raise ValueError(f"unknown {kind} backend {backend!r}; "
                         f"expected one of {valid}")
    return backend


@dataclasses.dataclass(frozen=True)
class CohortPolicy:
    """Parsed form of ``RoundContext.cohort`` — how the round driver walks
    the cohort.

      mode="vmap"    one vmap over all ``client_groups * n_clients`` clients
                     (plus the legacy sequential-group scan when groups > 1).
      mode="stream"  shard the flat cohort into ``shard``-client slices and
                     ``lax.scan`` them through the fused encode, folding each
                     shard's payload stack into ONE running wire accumulator
                     (compression.Pipeline.aggregate(..., acc=...)). Peak
                     memory O(d) model + O(shard * d/8) wire, any cohort size.
      mode="auto"    stream iff total_clients * n_coords >=
                     STREAM_AUTO_MIN_ELEMS (the small-run regression gate).

    ``shard == 0`` leaves the shard size to the engine (auto-tuned from the
    model coordinate count, see fedavg.auto_shard_size); a bare ``stream``
    spec therefore still auto-gates back to vmap below the threshold, while
    an explicit ``stream(shard=K)`` FORCES streaming at exactly K clients
    per shard (the bit-identity tests rely on this). ``shard=auto``
    (STREAM_SHARD_AUTO) also forces streaming, with the auto-tuned K.
    ``unroll`` is handed to the shard ``lax.scan`` to amortize loop
    overhead.

    ``devices`` adds the cross-device axis: the flat shard sequence is
    partitioned into contiguous per-device slices over a 1-D ``clients``
    mesh with ``shard_map``; each device runs the shard scan on its slice
    and the fp32 wire accumulators meet in ONE ``lax.psum`` (O(d) per device,
    independent of cohort size — the reduce stays in the compressed domain).
    ``devices=1`` (default) is the single-device scan; ``devices=auto``
    (COHORT_DEVICES_AUTO) expands to every local device; any other value
    pins the mesh size. Counter-based client keys make the bits invariant
    to device placement. ``feed`` selects device-resident shards (default)
    or the host-side double-buffered feeder (see COHORT_FEEDS);
    ``feed=host`` is single-device and its round step must not be jitted.
    """
    mode: str = "auto"
    shard: int = 0
    unroll: int = 1
    devices: int = 1
    feed: str = "device"

    def __post_init__(self):
        if self.mode not in COHORT_MODES:
            raise ValueError(f"unknown cohort mode {self.mode!r}; expected "
                             f"one of {COHORT_MODES}")
        if self.shard < STREAM_SHARD_AUTO or self.unroll < 1:
            raise ValueError(f"cohort policy needs shard >= 0 (or 'auto') "
                             f"and unroll >= 1, got shard={self.shard} "
                             f"unroll={self.unroll}")
        if self.devices < COHORT_DEVICES_AUTO:
            raise ValueError(f"cohort policy needs devices >= 1 (or 'auto'),"
                             f" got devices={self.devices}")
        if self.feed not in COHORT_FEEDS:
            raise ValueError(f"unknown cohort feed {self.feed!r}; expected "
                             f"one of {COHORT_FEEDS}")
        if self.mode != "stream":
            for name, val, default in (("shard", self.shard, 0),
                                       ("devices", self.devices, 1),
                                       ("feed", self.feed, "device")):
                if val != default:
                    raise ValueError(f"{name}={val!r} only applies to cohort "
                                     f"mode 'stream', not {self.mode!r}")
        if self.feed == "host" and self.devices != 1:
            raise ValueError("feed='host' is a single-device driver; it "
                             "cannot be combined with devices="
                             f"{self.devices!r}")

    @classmethod
    def parse(cls, spec: "str | CohortPolicy") -> "CohortPolicy":
        """``auto | vmap | stream |
        stream(shard=K|auto[,unroll=U][,devices=D|auto][,feed=device|host])``
        -> policy."""
        if isinstance(spec, cls):
            return spec
        s = spec.strip()
        if "(" not in s:
            return cls(mode=s)
        if not s.endswith(")"):
            raise ValueError(f"malformed cohort spec {spec!r}")
        mode, args = s[:-1].split("(", 1)
        kw = {}
        for part in filter(None, (p.strip() for p in args.split(","))):
            if "=" not in part:
                raise ValueError(f"cohort argument {part!r} in {spec!r} "
                                 f"must be key=value")
            k, v = part.split("=", 1)
            k, v = k.strip(), v.strip()
            if k not in ("shard", "unroll", "devices", "feed"):
                raise ValueError(f"unknown cohort argument {k!r} in "
                                 f"{spec!r}; expected shard=, unroll=, "
                                 f"devices= or feed=")
            if k == "feed":
                kw[k] = v
            elif k == "shard" and v == "auto":
                kw[k] = STREAM_SHARD_AUTO
            elif k == "devices" and v == "auto":
                kw[k] = COHORT_DEVICES_AUTO
            else:
                try:
                    iv = int(v)
                except ValueError:
                    raise ValueError(
                        f"cohort argument {part!r} in {spec!r} must be an "
                        f"integer" + (" or 'auto'"
                                      if k in ("shard", "devices") else "")
                    ) from None
                if iv < 0:
                    raise ValueError(f"cohort argument {part!r} in {spec!r} "
                                     f"must be non-negative")
                kw[k] = iv
        return cls(mode=mode.strip(), **kw)


@dataclasses.dataclass(frozen=True)
class RoundModePolicy:
    """Parsed form of ``RoundContext.round_mode`` — WHEN a round closes.

      mode="sync"    the classic barrier: the round folds every live
                     client's payload, however long the slowest takes.
      mode="async"   deadline-based close (fed/async_server.py): payloads
                     fold into the wire accumulator as they arrive; the
                     round closes at ``deadline`` simulated time units.
                     Clients that miss the deadline are governed by the
                     buffered-staleness law; clients that never report
                     (failures) take the dead-client mask semantics.

    ``deadline`` is required for async and is measured in the latency
    model's time units (one round's compute window). ``min_clients``
    extends the close past the deadline until at least that many live
    payloads have arrived (0 = never extend). ``staleness`` picks the law
    applied to a payload that computes in round r but arrives s > 0 rounds
    later (it folds into round r + s against the server's CURRENT params,
    carrying weight :meth:`stale_weight`):

      none        drop it (pure deadline cutoff)
      poly(a)     fold with weight (1 + s)^-a  (a >= 0)
      cutoff(s)   fold with full weight while s <= s_max, drop beyond

    Invariant (pinned in tests/test_async_server.py): zero latency and a
    deadline covering every client make the async round BIT-IDENTICAL —
    params, residuals, metrics — to the sync streaming round.
    """
    mode: str = "sync"
    deadline: float = 0.0
    min_clients: int = 0
    staleness: str = "none"
    staleness_arg: float = 0.0

    def __post_init__(self):
        if self.mode not in ROUND_MODES:
            raise ValueError(f"unknown round mode {self.mode!r}; expected "
                             f"one of {ROUND_MODES}")
        if self.staleness not in STALENESS_LAWS:
            raise ValueError(f"unknown staleness law {self.staleness!r}; "
                             f"expected one of {STALENESS_LAWS}")
        if self.mode == "sync":
            if (self.deadline, self.min_clients, self.staleness) != \
                    (0.0, 0, "none"):
                raise ValueError("deadline=/min_clients=/staleness= only "
                                 "apply to round mode 'async'")
        else:
            if not self.deadline > 0.0:
                raise ValueError("async round mode needs deadline > 0, got "
                                 f"deadline={self.deadline!r}")
        if self.min_clients < 0 or self.staleness_arg < 0.0:
            raise ValueError("min_clients and the staleness argument must "
                             "be non-negative")

    def stale_weight(self, s: int) -> float:
        """The closed-form buffered-staleness law: fold weight of a payload
        arriving ``s`` rounds after it was computed (s == 0 is on time)."""
        if s <= 0:
            return 1.0
        if self.staleness == "poly":
            return float((1.0 + s) ** (-self.staleness_arg))
        if self.staleness == "cutoff":
            return 1.0 if s <= self.staleness_arg else 0.0
        return 0.0

    @classmethod
    def parse(cls, spec: "str | RoundModePolicy") -> "RoundModePolicy":
        """``sync | async(deadline=T[,min_clients=M]
        [,staleness=none|poly(a)|cutoff(s)])`` -> policy."""
        if isinstance(spec, cls):
            return spec
        s = spec.strip()
        if "(" not in s:
            return cls(mode=s)
        if not s.endswith(")"):
            raise ValueError(f"malformed round_mode spec {spec!r}")
        mode, args = s[:-1].split("(", 1)
        kw = {}
        for part in _split_top(args):
            if "=" not in part:
                raise ValueError(f"round_mode argument {part!r} in {spec!r} "
                                 f"must be key=value")
            k, v = (t.strip() for t in part.split("=", 1))
            if k == "deadline":
                kw["deadline"] = float(v)
            elif k == "min_clients":
                kw["min_clients"] = int(v)
            elif k == "staleness":
                if "(" in v:
                    if not v.endswith(")"):
                        raise ValueError(f"malformed staleness law {v!r} in "
                                         f"{spec!r}")
                    law, arg = v[:-1].split("(", 1)
                    kw["staleness"] = law.strip()
                    kw["staleness_arg"] = float(arg)
                else:
                    kw["staleness"] = v
            else:
                raise ValueError(f"unknown round_mode argument {k!r} in "
                                 f"{spec!r}; expected deadline=, "
                                 f"min_clients= or staleness=")
        return cls(mode=mode.strip(), **kw)


@dataclasses.dataclass(frozen=True)
class RoundContext:
    """Frozen per-deployment policy for one federated round step.

    Constructed once by whoever owns the deployment decision (the train /
    dryrun CLIs, run_fed, a test) and handed to
    ``fedavg.build_round_step(loss_fn, compressor, cfg, ctx)``; the engine
    applies it to the compression pipeline via ``Pipeline.with_context`` and
    to its own client/aggregation paths. ``None`` backends defer to the
    pipeline stage's own config.
    """
    agg_backend: Optional[str] = None
    encode_backend: Optional[str] = None
    weights_are_mask: bool = False
    legacy_client_path: bool = False
    dynamic_sigma: bool = False
    donate_state: bool = True
    #: cohort execution policy for the round driver — a CohortPolicy spec
    #: string: "auto" | "vmap" | "stream" | "stream(shard=K|auto[,unroll=U]
    #: [,devices=D|auto][,feed=device|host])"
    cohort: str = "auto"
    #: debug-wire mode: insert a runtime checkify assertion that aggregation
    #: masks honor the 0/1 membership contract before every popcount/vote
    #: reduce (wire.check_mask_membership). Defaults from the
    #: REPRO_DEBUG_WIRE env var ("1"/"true" enables). A debug-wire round
    #: step must run eagerly or be functionalized:
    #: ``err, out = checkify.checkify(jax.jit(step))(...); err.throw()``.
    debug_wire: bool = dataclasses.field(
        default_factory=lambda: os.environ.get(
            "REPRO_DEBUG_WIRE", "").lower() in ("1", "true", "yes"))
    #: wire-level fault-injection policy for the round driver — an
    #: fed/adversary.py spec string: "none" | "sign_flip(f=4)" |
    #: "byte_corrupt(f=2,p=0.1)" | "collude(f=4)" | "dropout(f=8)"
    #: (+ schedule args every=/start=/rotate=/seed=)
    adversary: str = "none"
    #: round execution mode — a RoundModePolicy spec string: "sync" |
    #: "async(deadline=T[,min_clients=M][,staleness=none|poly(a)|
    #: cutoff(s)])". Async rounds are driven by fed/async_server.py: a
    #: host-side event loop that folds payloads into the wire accumulator
    #: as they arrive and closes at the deadline. An async round step is a
    #: Python loop — it must NOT be wrapped in jax.jit.
    round_mode: str = "sync"
    #: simulated client latency/failure model for async rounds — an
    #: fed/async_server.py spec string: "zero" | "const(t=T)" |
    #: "linear(base=B,step=S)" | "lognormal(median=M,sigma=S)" |
    #: "pareto(xm=X,alpha=A)" (+ fail=P failure rate, seed=N). Only
    #: meaningful with round_mode="async".
    latency: str = "zero"

    def __post_init__(self):
        # fail at construction, not at trace time inside the round step —
        # membership is owned by resolve_backend / CohortPolicy, reused here
        for kind, backend in (("agg", self.agg_backend),
                              ("encode", self.encode_backend)):
            if backend is not None:
                resolve_backend(kind, backend)
        CohortPolicy.parse(self.cohort)
        mode = RoundModePolicy.parse(self.round_mode)
        if self.latency != "zero":
            if mode.mode != "async":
                raise ValueError("latency= is a simulation knob of async "
                                 "rounds; set round_mode='async(...)' or "
                                 "leave latency='zero'")
            # validate eagerly; imported lazily to keep core free of a
            # module-load dependency on the fed layer
            from repro.fed.async_server import parse_latency
            parse_latency(self.latency)
        if self.adversary != "none":
            # validate eagerly; imported lazily to keep core free of a
            # module-load dependency on the fed layer
            from repro.fed.adversary import parse_adversary
            parse_adversary(self.adversary)
