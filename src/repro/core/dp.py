"""DP-SignFedAvg (paper Algorithm 2 + Appendix F).

Client-side: clip the pseudo-gradient to norm C, add N(0, sigma^2 C^2 I),
transmit the sign — i.e. ZSignCompressor with z=1 where the *same* Gaussian
noise provides both the DP guarantee and the sign-bias correction.

Accounting: Renyi-DP of the subsampled Gaussian mechanism (Mironov, Talwar,
Zhang 2019) with the integer-alpha closed form, converted to (eps, delta)-DP.
The clipping + noise themselves live in core/fedavg.py (cfg.dp_clip > 0) +
ZSignCompressor(sigma=noise_multiplier * C).
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp


def clip_flat(flat: jax.Array, max_norm: float) -> jax.Array:
    """L2-clip a flat buffer to ``max_norm`` (Algorithm 2 line 9).

    The shared client-side clipping primitive: the round engine applies it
    when ``FedConfig.dp_clip > 0`` and the ``dp`` pipeline transform
    (compression.DPTransform) applies it stage-side — both are the same
    function so the two spellings are bit-identical.
    """
    nrm = jnp.linalg.norm(flat)
    return flat * (1.0 / jnp.maximum(1.0, nrm / max_norm))


def _log_comb(n: int, k: int) -> float:
    return (math.lgamma(n + 1) - math.lgamma(k + 1) - math.lgamma(n - k + 1))


def rdp_subsampled_gaussian(q: float, noise_multiplier: float,
                            steps: int, alphas: Sequence[int]) -> list:
    """RDP epsilon at each integer alpha after ``steps`` compositions.

    For q == 1 (full participation) uses the exact Gaussian-mechanism RDP
    alpha / (2 sigma^2); otherwise the binomial-expansion upper bound for the
    sampled Gaussian mechanism (valid for integer alpha >= 2).
    """
    sig = noise_multiplier
    out = []
    for a in alphas:
        if a < 2:
            raise ValueError("alpha must be >= 2")
        if q >= 1.0:
            eps_a = a / (2.0 * sig * sig)
        else:
            # log E_{k~Bin(alpha,q)} exp(k(k-1)/(2 sigma^2))
            log_terms = [
                _log_comb(a, k) + k * math.log(q) + (a - k) * math.log1p(-q)
                + k * (k - 1) / (2.0 * sig * sig)
                for k in range(a + 1)
            ]
            m = max(log_terms)
            log_mgf = m + math.log(sum(math.exp(t - m) for t in log_terms))
            eps_a = log_mgf / (a - 1)
        out.append(steps * eps_a)
    return out


def compute_epsilon(q: float, noise_multiplier: float, steps: int,
                    delta: float, alphas: Sequence[int] = tuple(range(2, 256))) -> float:
    """(eps, delta)-DP from the optimal RDP order."""
    rdp = rdp_subsampled_gaussian(q, noise_multiplier, steps, alphas)
    eps = min(r + math.log(1.0 / delta) / (a - 1) for r, a in zip(rdp, alphas))
    return eps


def calibrate_noise(q: float, steps: int, target_eps: float, delta: float,
                    lo: float = 0.3, hi: float = 50.0, iters: int = 60) -> float:
    """Smallest noise multiplier achieving (target_eps, delta)-DP (bisection)."""
    if compute_epsilon(q, hi, steps, delta) > target_eps:
        raise ValueError("target epsilon unreachable within noise bound")
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        if compute_epsilon(q, mid, steps, delta) > target_eps:
            lo = mid
        else:
            hi = mid
    return hi
