"""Fault-tolerant, mesh-independent checkpointing.

Design goals (DESIGN.md §6):
  * atomic: write to <dir>/.tmp-<round>, fsync, rename -> a crash mid-write
    never corrupts the latest checkpoint;
  * self-validating: SHA-256 digest stored next to the payload; restore
    skips checkpoints whose digest mismatches (torn writes / bitrot) and
    falls back to the previous one;
  * mesh-independent (elastic): arrays are saved *unsharded* as host numpy
    under flattened pytree paths; ``restore`` re-shards onto whatever mesh /
    sharding the restarted job passes — pods may come and go between runs;
  * bounded retention: keep the newest ``keep`` checkpoints.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, round_idx: int, state_tree: Any, extra: Optional[dict] = None):
        flat = _flatten(state_tree)
        tmp = os.path.join(self.dir, f".tmp-{round_idx}")
        final = os.path.join(self.dir, f"ckpt-{round_idx:08d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        payload = os.path.join(tmp, "arrays.npz")
        np.savez(payload, **flat)
        digest = _sha256(payload)
        meta = {"round": round_idx, "digest": digest,
                "keys": sorted(flat.keys()), "extra": extra or {}}
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        return final

    def _gc(self):
        ckpts = self._list()
        for _, path in ckpts[:-self.keep]:
            shutil.rmtree(path, ignore_errors=True)

    def _list(self):
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"ckpt-(\d+)", name)
            if m:
                out.append((int(m.group(1)), os.path.join(self.dir, name)))
        return sorted(out)

    # -- restore ------------------------------------------------------------
    def restore_latest(self, template_tree: Any, shardings: Any = None):
        """Returns (round_idx, tree) or (None, None). Walks backwards past
        corrupt checkpoints (digest mismatch / unreadable)."""
        for round_idx, path in reversed(self._list()):
            try:
                with open(os.path.join(path, "meta.json")) as f:
                    meta = json.load(f)
                payload = os.path.join(path, "arrays.npz")
                if _sha256(payload) != meta["digest"]:
                    raise IOError("digest mismatch")
                data = np.load(payload)
                tree = self._unflatten(template_tree, data, shardings)
                return round_idx, tree
            except Exception:
                continue
        return None, None

    @staticmethod
    def _unflatten(template, data, shardings):
        flat_t = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        shard_leaves = (jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: x is None) if shardings is not None
            else [None] * len(flat_t[0]))
        for (path, leaf), shard in zip(flat_t[0], shard_leaves):
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            arr = np.asarray(data[key])
            if shard is not None:
                leaves.append(jax.device_put(arr, shard))
            else:
                leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
        return jax.tree_util.tree_unflatten(flat_t[1], leaves)


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()
