"""Pallas TPU kernels for z-SignFedAvg's compression hot path.

Three kernels:

  _compress_kernel:  y = x + sigma*noise; pack Sign(y) bits -> uint8
                     (fused elementwise + 8:1 bitpack; 1 byte out per 8 in)
  _unpack_sum_kernel: (n_clients, ...) packed uint8 -> sum of {-1,+1} fp32
                     (legacy whole-stack unpack; kept as kernel oracle)
  _sign_reduce_kernel: (n_clients, ...) packed uint8 + (n_clients,) fp32
                     weights -> weighted sum of {-1,+1} fp32, with the client
                     axis folded into the grid and a VMEM accumulator per
                     output tile. This is the fused server aggregation: the
                     dense (n_clients, d) fp32 sign matrix never exists —
                     each grid step expands one CLIENT_BLK x tile slab of
                     wire bytes in VMEM, multiplies by the per-client
                     weights, and accumulates into the revisited output tile.

TPU adaptation notes (DESIGN.md §2): the compressor is bandwidth-bound
elementwise work, so the kernels stream HBM->VMEM in (ROWS_BLK, 1024) tiles
(1024 = 8 lanes-groups x 128 lanes, MXU-free, VPU-only) and write uint8 tiles
(ROWS_BLK, 128). Bit order matches the flat little-endian order of the
pure-jnp oracle in ref.py (element 8i+j -> bit j of byte i). On real TPU the
noise would be generated in-kernel via pltpu.prng_random_bits; here noise is
a kernel input so interpret-mode (CPU) validation is exact vs the oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
PACK = 8
COLS = LANE * PACK          # 1024 elements per row
ROWS_BLK = 8                # 8192 elements per block
CLIENT_BLK = 8              # clients per sign-reduce grid step


def _compress_kernel(x_ref, n_ref, sig_ref, o_ref):
    x = x_ref[...]                                   # (R, 1024) f32
    y = x + sig_ref[0, 0] * n_ref[...]
    r = x.shape[0]
    bits = (y >= 0.0).reshape(r, LANE, PACK).astype(jnp.uint8)
    weights = (jnp.uint8(1) << jnp.arange(PACK, dtype=jnp.uint8))
    o_ref[...] = jnp.sum(bits * weights, axis=-1, dtype=jnp.uint8)


def compress_pallas(x2d: jax.Array, noise2d: jax.Array, sigma: jax.Array,
                    *, interpret: bool) -> jax.Array:
    """x2d/noise2d: (rows, 1024) f32, rows % ROWS_BLK == 0 -> (rows, 128) u8."""
    rows = x2d.shape[0]
    grid = (rows // ROWS_BLK,)
    return pl.pallas_call(
        _compress_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROWS_BLK, COLS), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_BLK, COLS), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((ROWS_BLK, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANE), jnp.uint8),
        interpret=interpret,
    )(x2d, noise2d, sigma.reshape(1, 1).astype(jnp.float32))


def _unpack_sum_kernel(p_ref, o_ref):
    p = p_ref[...]                                   # (n, R, 128) u8
    weights = (jnp.uint8(1) << jnp.arange(PACK, dtype=jnp.uint8))
    bits = (p[..., None] & weights) > 0              # (n, R, 128, 8)
    pm = jnp.where(bits, jnp.float32(1), jnp.float32(-1))
    s = jnp.sum(pm, axis=0)                          # (R, 128, 8)
    o_ref[...] = s.reshape(s.shape[0], COLS)


def unpack_sum_pallas(packed: jax.Array, *, interpret: bool) -> jax.Array:
    """packed: (n_clients, rows, 128) u8 -> (rows, 1024) f32 sum of signs."""
    n, rows, _ = packed.shape
    grid = (rows // ROWS_BLK,)
    return pl.pallas_call(
        _unpack_sum_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((n, ROWS_BLK, LANE), lambda i: (0, i, 0))],
        out_specs=pl.BlockSpec((ROWS_BLK, COLS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, COLS), jnp.float32),
        interpret=interpret,
    )(packed)


def _sign_reduce_kernel(p_ref, w_ref, o_ref):
    c = pl.program_id(1)
    p = p_ref[...]                                   # (CB, R, 128) u8
    w = w_ref[...].reshape(-1, 1, 1, 1)              # (CB, 1, 1, 1) f32
    bitw = (jnp.uint8(1) << jnp.arange(PACK, dtype=jnp.uint8))
    bits = (p[..., None] & bitw) > 0                 # (CB, R, 128, 8)
    pm = jnp.where(bits, jnp.float32(1), jnp.float32(-1))
    part = jnp.sum(pm * w, axis=0)                   # (R, 128, 8)
    part = part.reshape(part.shape[0], COLS)

    @pl.when(c == 0)
    def _init():
        o_ref[...] = part

    @pl.when(c != 0)
    def _acc():
        o_ref[...] = o_ref[...] + part


def sign_reduce_pallas(packed: jax.Array, weights: jax.Array,
                       *, interpret: bool) -> jax.Array:
    """packed: (n_clients, rows, 128) u8, weights: (n_clients, 1) f32 ->
    (rows, 1024) f32 weighted sum of signs.

    n_clients % CLIENT_BLK == 0 and rows % ROWS_BLK == 0 (caller pads; dead
    or padded clients carry weight 0 and contribute exactly 0). The client
    axis is the INNER grid dimension, so each output tile stays resident in
    VMEM while every client block streams past it — the server's working set
    is one wire slab + one fp32 tile, never the (n_clients, d) sign matrix.
    """
    n, rows, _ = packed.shape
    grid = (rows // ROWS_BLK, n // CLIENT_BLK)
    return pl.pallas_call(
        _sign_reduce_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((CLIENT_BLK, ROWS_BLK, LANE), lambda i, c: (c, i, 0)),
            pl.BlockSpec((CLIENT_BLK, 1), lambda i, c: (c, 0)),
        ],
        out_specs=pl.BlockSpec((ROWS_BLK, COLS), lambda i, c: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, COLS), jnp.float32),
        interpret=interpret,
    )(packed, weights.astype(jnp.float32))
