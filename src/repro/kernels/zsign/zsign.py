"""Pallas TPU kernels for z-SignFedAvg's compression hot path.

Four kernels:

  _compress_kernel:  y = x + sigma*noise; pack Sign(y) bits -> uint8
                     (fused elementwise + 8:1 bitpack; 1 byte out per 8 in;
                     noise is a kernel INPUT — the legacy/dense-noise path,
                     kept for finite z > 1 and as the reference encoder)
  _compress_rng_kernel: in-kernel counter-based noise — each grid tile
                     derives its randomness from threefry2x32(client_key,
                     tile_counters) (core/noise.py, plain VPU uint32 ops; 4
                     u16 uniforms per call) and samples the wire bit
                     directly from its exact Bernoulli law
                     [u > 1 - P_z(x/sigma)] (the inverse-CDF coupling of
                     noise.stochastic_sign_bits). The fp32 noise buffer that
                     the old path streamed through HBM never exists: the
                     client encode reads x and writes wire bytes, nothing
                     else. Counters are GLOBAL quarter-tile indices, so the
                     chunked jnp fallback (core/compression.py) reproduces
                     the byte stream bit-exactly on CPU.
  _unpack_sum_kernel: (n_clients, ...) packed uint8 -> sum of {-1,+1} fp32
                     (legacy whole-stack unpack; kept as kernel oracle)
  _sign_reduce_kernel: (n_clients, ...) packed uint8 + (n_clients,) fp32
                     weights -> weighted sum of {-1,+1} fp32, with the client
                     axis folded into the grid and a VMEM accumulator per
                     output tile. This is the fused server aggregation: the
                     dense (n_clients, d) fp32 sign matrix never exists —
                     each grid step expands one CLIENT_BLK x tile slab of
                     wire bytes in VMEM, multiplies by the per-client
                     weights, and accumulates into the revisited output tile.

TPU adaptation notes (DESIGN.md §2): the compressor is bandwidth-bound
elementwise work, so the kernels stream HBM->VMEM in (ROWS_BLK, 1024) tiles
(1024 = 8 lanes-groups x 128 lanes, MXU-free, VPU-only) and write uint8 tiles
(ROWS_BLK, 128). Bit order matches the flat little-endian order of the
pure-jnp oracle in ref.py (element 8i+j -> bit j of byte i). The counter
scheme was chosen over pltpu.prng_random_bits because the hardware PRNG's
stream cannot be reproduced off-TPU — threefry2x32 is ~13 VPU integer ops
per word and gives the interpret-mode kernel, the compiled TPU kernel, and
the jnp fallback the identical byte stream for the same client key.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import noise as znoise

LANE = 128
PACK = 8
COLS = LANE * PACK          # 1024 elements per row
ROWS_BLK = 8                # 8192 elements per block
CLIENT_BLK = 8              # clients per sign-reduce grid step


def _compress_kernel(x_ref, n_ref, sig_ref, o_ref):
    x = x_ref[...]                                   # (R, 1024) f32
    y = x + sig_ref[0, 0] * n_ref[...]
    r = x.shape[0]
    bits = (y >= 0.0).reshape(r, LANE, PACK).astype(jnp.uint8)
    weights = (jnp.uint8(1) << jnp.arange(PACK, dtype=jnp.uint8))
    o_ref[...] = jnp.sum(bits * weights, axis=-1, dtype=jnp.uint8)


def compress_pallas(x2d: jax.Array, noise2d: jax.Array, sigma: jax.Array,
                    *, interpret: bool) -> jax.Array:
    """x2d/noise2d: (rows, 1024) f32, rows % ROWS_BLK == 0 -> (rows, 128) u8."""
    rows = x2d.shape[0]
    grid = (rows // ROWS_BLK,)
    return pl.pallas_call(
        _compress_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROWS_BLK, COLS), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_BLK, COLS), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((ROWS_BLK, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANE), jnp.uint8),
        interpret=interpret,
    )(x2d, noise2d, sigma.reshape(1, 1).astype(jnp.float32))


def _pack_bits_u8(bits):
    """(R, COLS) bool -> (R, LANE) uint8, little-endian within each byte."""
    r = bits.shape[0]
    b = bits.reshape(r, LANE, PACK).astype(jnp.uint8)
    weights = (jnp.uint8(1) << jnp.arange(PACK, dtype=jnp.uint8))
    return jnp.sum(b * weights, axis=-1, dtype=jnp.uint8)


def _compress_rng_kernel(x_ref, k_ref, sig_ref, t_ref, o_ref, *, z):
    """Counter-based in-kernel noise: one tile of the fused client encode.

    Tile t covers elements [t*8192, (t+1)*8192). Quarter-counters are global
    (c = t*2048 + local); one threefry2x32 call yields 4 u16 uniforms that
    feed the tile's four row-quarters — the layout of noise.tile_u01, which
    the jnp fallback replays verbatim. ``z`` is static: None disables the
    noise entirely (vanilla SignSGD, satellite of the sigma==0 gating), else
    z in {Z_INF, 1} selects the sign CDF.
    """
    x = x_ref[...]                                   # (R, 1024) f32
    if z is None:
        o_ref[...] = _pack_bits_u8(x >= 0.0)
        return
    r = x.shape[0]
    qrows = r // 4
    t = t_ref[0, 0].astype(jnp.uint32)
    row = jax.lax.broadcasted_iota(jnp.uint32, (qrows, COLS), 0)
    col = jax.lax.broadcasted_iota(jnp.uint32, (qrows, COLS), 1)
    c = t * jnp.uint32(r * COLS // 4) + row * jnp.uint32(COLS) + col
    y0, y1 = znoise.counter_words(k_ref[0, 0], k_ref[0, 1], c)
    u0, u1 = znoise.halves_to_u01(y0)
    u2, u3 = znoise.halves_to_u01(y1)
    u = jnp.concatenate([u0, u1, u2, u3], axis=0)    # (R, 1024) in (0,1)
    o_ref[...] = _pack_bits_u8(
        znoise.stochastic_sign_bits(x, u, sig_ref[0, 0], z))


def compress_rng_pallas(x2d: jax.Array, key2: jax.Array, sigma: jax.Array,
                        *, z, interpret: bool) -> jax.Array:
    """x2d: (rows, 1024) f32 (rows % ROWS_BLK == 0), key2: (1, 2) uint32 ->
    (rows, 128) u8 with noise generated inside each grid step."""
    rows = x2d.shape[0]
    n_tiles = rows // ROWS_BLK
    tiles = jnp.arange(n_tiles, dtype=jnp.int32).reshape(-1, 1)
    return pl.pallas_call(
        functools.partial(_compress_rng_kernel, z=z),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((ROWS_BLK, COLS), lambda i: (i, 0)),
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((ROWS_BLK, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANE), jnp.uint8),
        interpret=interpret,
    )(x2d, key2, sigma.reshape(1, 1).astype(jnp.float32), tiles)


def compress_rng_pallas_batched(x2d: jax.Array, key2: jax.Array,
                                sigma: jax.Array, *, z,
                                interpret: bool) -> jax.Array:
    """Client-batched fused encode: the vmap lowering of
    :func:`compress_rng_pallas`, with the client axis folded into the GRID.

    x2d: (n * rows, 1024) f32 — n clients' padded rows stacked contiguously
    (rows % ROWS_BLK == 0); key2: (n, 2) uint32; sigma: (n,) f32 ->
    (n * rows, 128) u8.

    Same kernel body as the unbatched call: the tile-id operand carries the
    client-LOCAL tile index and the key/sigma BlockSpecs select client c's
    row, so every client sees exactly the counter stream of its own
    unbatched call — bit-identical bytes. Folding the batch into the grid
    (instead of letting vmap batch the pallas_call) keeps each grid step's
    output write loop-indexed: JAX's pallas batching rule would instead
    add the client axis to every dynamic-update-slice, which XLA lowers to
    a per-tile copy of the WHOLE (n, rows, 128) buffer — the measured
    superlinear per-client encode cost at vmap widths >= 64.
    """
    n = key2.shape[0]
    rows_all = x2d.shape[0]
    n_tiles = rows_all // n // ROWS_BLK
    tiles = jnp.arange(n_tiles, dtype=jnp.int32).reshape(-1, 1)
    return pl.pallas_call(
        functools.partial(_compress_rng_kernel, z=z),
        grid=(n, n_tiles),
        in_specs=[
            pl.BlockSpec((ROWS_BLK, COLS), lambda c, i: (c * n_tiles + i, 0)),
            pl.BlockSpec((1, 2), lambda c, i: (c, 0)),
            pl.BlockSpec((1, 1), lambda c, i: (c, 0)),
            pl.BlockSpec((1, 1), lambda c, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((ROWS_BLK, LANE), lambda c, i: (c * n_tiles + i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows_all, LANE), jnp.uint8),
        interpret=interpret,
    )(x2d, key2, sigma.reshape(-1, 1).astype(jnp.float32), tiles)


def _unpack_sum_kernel(p_ref, o_ref):
    p = p_ref[...]                                   # (n, R, 128) u8
    weights = (jnp.uint8(1) << jnp.arange(PACK, dtype=jnp.uint8))
    bits = (p[..., None] & weights) > 0              # (n, R, 128, 8)
    pm = jnp.where(bits, jnp.float32(1), jnp.float32(-1))
    s = jnp.sum(pm, axis=0)                          # (R, 128, 8)
    o_ref[...] = s.reshape(s.shape[0], COLS)


def unpack_sum_pallas(packed: jax.Array, *, interpret: bool) -> jax.Array:
    """packed: (n_clients, rows, 128) u8 -> (rows, 1024) f32 sum of signs."""
    n, rows, _ = packed.shape
    grid = (rows // ROWS_BLK,)
    return pl.pallas_call(
        _unpack_sum_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((n, ROWS_BLK, LANE), lambda i: (0, i, 0))],
        out_specs=pl.BlockSpec((ROWS_BLK, COLS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, COLS), jnp.float32),
        interpret=interpret,
    )(packed)


def _sign_reduce_kernel(p_ref, w_ref, o_ref):
    c = pl.program_id(1)
    p = p_ref[...]                                   # (CB, R, 128) u8
    w = w_ref[...].reshape(-1, 1, 1, 1)              # (CB, 1, 1, 1) f32
    bitw = (jnp.uint8(1) << jnp.arange(PACK, dtype=jnp.uint8))
    bits = (p[..., None] & bitw) > 0                 # (CB, R, 128, 8)
    pm = jnp.where(bits, jnp.float32(1), jnp.float32(-1))
    part = jnp.sum(pm * w, axis=0)                   # (R, 128, 8)
    part = part.reshape(part.shape[0], COLS)

    @pl.when(c == 0)
    def _init():
        o_ref[...] = part

    @pl.when(c != 0)
    def _acc():
        o_ref[...] = o_ref[...] + part


def sign_reduce_pallas(packed: jax.Array, weights: jax.Array,
                       *, interpret: bool) -> jax.Array:
    """packed: (n_clients, rows, 128) u8, weights: (n_clients, 1) f32 ->
    (rows, 1024) f32 weighted sum of signs.

    n_clients % CLIENT_BLK == 0 and rows % ROWS_BLK == 0 (caller pads; dead
    or padded clients carry weight 0 and contribute exactly 0). The client
    axis is the INNER grid dimension, so each output tile stays resident in
    VMEM while every client block streams past it — the server's working set
    is one wire slab + one fp32 tile, never the (n_clients, d) sign matrix.
    """
    n, rows, _ = packed.shape
    grid = (rows // ROWS_BLK, n // CLIENT_BLK)
    return pl.pallas_call(
        _sign_reduce_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((CLIENT_BLK, ROWS_BLK, LANE), lambda i, c: (c, i, 0)),
            pl.BlockSpec((CLIENT_BLK, 1), lambda i, c: (c, 0)),
        ],
        out_specs=pl.BlockSpec((ROWS_BLK, COLS), lambda i, c: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, COLS), jnp.float32),
        interpret=interpret,
    )(packed, weights.astype(jnp.float32))
