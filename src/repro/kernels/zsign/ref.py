"""Pure-jnp oracle for the z-sign compression kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.noise import sample_z_noise


def zsign_compress_ref(x: jax.Array, noise: jax.Array, sigma: float) -> jax.Array:
    """Noisy sign + bitpack, reference.

    x, noise: flat float32, length % 8 == 0 -> uint8 of length // 8.
    bit j of byte i  ==  Sign(x[8i+j] + sigma*noise[8i+j]) > 0.
    """
    y = x + sigma * noise
    bits = (y >= 0).astype(jnp.uint8).reshape(-1, 8)
    weights = (jnp.uint8(1) << jnp.arange(8, dtype=jnp.uint8))
    return jnp.sum(bits * weights, axis=-1).astype(jnp.uint8)


def zsign_decompress_sum_ref(packed: jax.Array) -> jax.Array:
    """(n_clients, L/8) uint8 -> (L,) float32 sum of {-1,+1} across clients."""
    n = packed.shape[0]
    weights = (jnp.uint8(1) << jnp.arange(8, dtype=jnp.uint8))
    bits = (packed[..., None] & weights) > 0                  # (n, L/8, 8)
    pm = jnp.where(bits, 1.0, -1.0).reshape(n, -1)
    return jnp.sum(pm, axis=0)


def sign_reduce_ref(packed: jax.Array, weights: jax.Array) -> jax.Array:
    """Dense-matrix oracle for the fused weighted sign-reduce.

    (n_clients, L/8) uint8 + (n_clients,) f32 -> (L,) f32 weighted sum of
    {-1,+1}, deliberately materializing the full (n_clients, L) fp32 sign
    matrix — the thing the production paths must never do.
    """
    n = packed.shape[0]
    bit_w = (jnp.uint8(1) << jnp.arange(8, dtype=jnp.uint8))
    bits = (packed[..., None] & bit_w) > 0                    # (n, L/8, 8)
    pm = jnp.where(bits, 1.0, -1.0).reshape(n, -1)
    return jnp.einsum("nd,n->d", pm, weights.astype(jnp.float32))
