from repro.kernels.zsign.ops import (sign_reduce, zsign_compress,  # noqa: F401
                                     zsign_decompress_sum)
