from repro.kernels.zsign.ops import zsign_compress, zsign_decompress_sum  # noqa: F401
