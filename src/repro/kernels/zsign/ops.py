"""jit'd public wrappers around the z-sign Pallas kernels.

Handle arbitrary-shaped inputs (flatten + pad to the 8192-element tile), and
select interpret mode automatically off-TPU so the same code validates on CPU
and runs the real kernel on TPU.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import noise as znoise
from repro.kernels.zsign import zsign as K

TILE = K.ROWS_BLK * K.COLS   # 8192


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_flat(x: jax.Array):
    flat = x.reshape(-1)
    pad = (-flat.size) % TILE
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, K.COLS), pad


@partial(jax.jit, static_argnames=("interpret",))
def zsign_compress(x: jax.Array, noise: jax.Array, sigma,
                   *, interpret: bool | None = None) -> jax.Array:
    """Fused noisy-sign + bitpack.  x, noise: same shape float32.
    Returns uint8 of ceil(x.size/8) bytes (padded tail packs sign(+pad zeros)).
    """
    interpret = _interpret() if interpret is None else interpret
    x2d, _ = _pad_flat(x.astype(jnp.float32))
    n2d, _ = _pad_flat(noise.astype(jnp.float32))
    packed = K.compress_pallas(x2d, n2d, jnp.asarray(sigma), interpret=interpret)
    return packed.reshape(-1)


@partial(jax.jit, static_argnames=("z", "add_noise", "interpret"))
def zsign_encode_fused(x: jax.Array, key: jax.Array, sigma,
                       *, z: int, add_noise: bool = True,
                       interpret: bool | None = None) -> jax.Array:
    """Fused client encode with IN-KERNEL counter-based noise.

    x: any-shape float32; key: the client's PRNG key (typed or raw uint32
    pair). Each 8192-element grid tile derives its randomness from
    threefry2x32(key, global_counters) and writes Sign(x + sigma*xi_z) as
    wire bytes directly — no fp32 noise buffer in HBM, unlike
    ``zsign_compress`` which takes a dense noise input. Returns uint8 of
    ceil(x.size/8192)*1024 bytes (kernel tile padding, as zsign_compress).
    ``z`` must be Z_INF (uniform) or 1 (Gaussian); ``add_noise=False``
    (static sigma == 0, vanilla SignSGD) skips the PRNG entirely. ``sigma``
    may be traced (Plateau dynamic sigma; stosign's per-client norm) — a
    runtime 0 also degrades exactly to noise-free signs.
    """
    interpret = _interpret() if interpret is None else interpret
    x2d, _ = _pad_flat(x.astype(jnp.float32))
    k0, k1 = znoise.key_words(key)
    key2 = jnp.stack([k0, k1]).reshape(1, 2)
    packed = K.compress_rng_pallas(
        x2d, key2, jnp.asarray(sigma), z=(z if add_noise else None),
        interpret=interpret)
    return packed.reshape(-1)


@partial(jax.jit, static_argnames=("interpret",))
def sign_reduce(packed: jax.Array, weights: jax.Array,
                *, interpret: bool | None = None) -> jax.Array:
    """Fused weighted sign-reduce: (n_clients, n_bytes) u8 + (n_clients,)
    f32 -> (8*n_bytes,) f32 weighted sum of the +/-1 signs.

    ONE kernel launch for the whole client stack (clients folded into the
    grid, VMEM accumulator per output tile) — replaces the per-client-row
    vmap over ``zsign_decompress_sum``. Clients are padded to CLIENT_BLK
    with zero weight, bytes to the (ROWS_BLK * LANE) tile; both pads
    contribute exactly 0.
    """
    interpret = _interpret() if interpret is None else interpret
    n, nbytes = packed.shape
    bpad = (-nbytes) % (K.ROWS_BLK * K.LANE)
    cpad = (-n) % K.CLIENT_BLK
    if bpad or cpad:
        packed = jnp.pad(packed, ((0, cpad), (0, bpad)))
    w = weights.astype(jnp.float32)
    if cpad:
        w = jnp.pad(w, (0, cpad))
    p3 = packed.reshape(n + cpad, -1, K.LANE)
    s = K.sign_reduce_pallas(p3, w.reshape(-1, 1), interpret=interpret)
    return s.reshape(-1)[: nbytes * 8]


@partial(jax.jit, static_argnames=("n_coords", "interpret"))
def zsign_decompress_sum(packed: jax.Array, n_coords: int,
                         *, interpret: bool | None = None) -> jax.Array:
    """packed: (n_clients, n_bytes) uint8 -> (n_coords,) f32 sum of signs."""
    interpret = _interpret() if interpret is None else interpret
    n, nbytes = packed.shape
    pad = (-nbytes) % (K.ROWS_BLK * K.LANE)
    if pad:
        packed = jnp.pad(packed, ((0, 0), (0, pad)))
    p3 = packed.reshape(n, -1, K.LANE)
    s = K.unpack_sum_pallas(p3, interpret=interpret).reshape(-1)
    return s[:n_coords]
