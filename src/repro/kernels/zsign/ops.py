"""jit'd public wrappers around the z-sign Pallas kernels.

Handle arbitrary-shaped inputs (flatten + pad to the 8192-element tile), and
select interpret mode automatically off-TPU so the same code validates on CPU
and runs the real kernel on TPU.
"""
from __future__ import annotations

from functools import lru_cache, partial

import jax
import jax.numpy as jnp

from repro.core import noise as znoise
from repro.kernels.zsign import zsign as K

TILE = K.ROWS_BLK * K.COLS   # 8192


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_flat(x: jax.Array):
    flat = x.reshape(-1)
    pad = (-flat.size) % TILE
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, K.COLS), pad


@partial(jax.jit, static_argnames=("interpret",))
def zsign_compress(x: jax.Array, noise: jax.Array, sigma,
                   *, interpret: bool | None = None) -> jax.Array:
    """Fused noisy-sign + bitpack.  x, noise: same shape float32.
    Returns uint8 of ceil(x.size/8) bytes (padded tail packs sign(+pad zeros)).
    """
    interpret = _interpret() if interpret is None else interpret
    x2d, _ = _pad_flat(x.astype(jnp.float32))
    n2d, _ = _pad_flat(noise.astype(jnp.float32))
    packed = K.compress_pallas(x2d, n2d, jnp.asarray(sigma), interpret=interpret)
    return packed.reshape(-1)


def _batched_encode_tiles_jnp(x2d, key2, sigma, *, z):
    """Client-batched counter-stream encode, pure jnp, tile-scanned.

    x2d: (n, rows, 1024) f32; key2: (n, 2) u32; sigma: (n,) f32 ->
    (n, rows, 128) u8, byte-for-byte the stack of per-client
    ``compress_rng_pallas`` outputs (same global quarter-counters, same
    tile word layout — noise.tile_u01). The lax.scan walks the TILE axis
    so the largest computed f32 intermediate is one (n, 8192) uniform
    window, never an (n, d) noise surface (the jaxpr pin of
    tests/test_encode_fused.py)."""
    n, rows, _ = x2d.shape
    if z is None:
        return jax.vmap(K._pack_bits_u8)(x2d >= 0.0)
    n_tiles = rows // K.ROWS_BLK
    k0, k1 = key2[:, 0], key2[:, 1]
    sig = sigma.reshape(n, 1)
    xt = jnp.moveaxis(x2d.reshape(n, n_tiles, TILE), 1, 0)

    def step(_, xs):
        x_t, t = xs                                   # (n, 8192), () u32
        u = jax.vmap(lambda a, b: znoise.tile_u01(a, b, t * TILE, TILE))(
            k0, k1)
        bits = znoise.stochastic_sign_bits(x_t, u, sig, z)
        return None, K._pack_bits_u8(bits.reshape(n * K.ROWS_BLK, K.COLS))

    _, packed = jax.lax.scan(
        step, None, (xt, jnp.arange(n_tiles, dtype=jnp.uint32)))
    # (n_tiles, n*ROWS_BLK, LANE) -> per-client (rows, LANE), tile-major
    return jnp.moveaxis(packed.reshape(n_tiles, n, K.ROWS_BLK, K.LANE),
                        1, 0).reshape(n, rows, K.LANE)


@lru_cache(maxsize=None)
def _rng_encode_vmappable(z, interpret: bool):
    """The pallas_call site of ``zsign_encode_fused`` with a custom vmap
    rule (cached per static (z, interpret) since custom_vmap carries no
    static args).

    JAX's default pallas batching rule appends the mapped client axis to
    the grid, and in interpret mode every grid step then re-materializes
    the whole (n, rows, 128) output via a batched dynamic-update-slice —
    per-client encode cost grows ~linearly with the vmap width (measured
    50 -> 1560 us/client from n=16 to n=256 at d=1024). The rule here
    replaces that lowering wholesale:

      * compiled TPU path: :func:`zsign.compress_rng_pallas_batched` folds
        the client axis into the kernel GRID — block-pipelined in-place
        writes, one kernel launch, linear in n;
      * interpret/CPU path: the tile-scanned jnp twin
        (:func:`_batched_encode_tiles_jnp`) — an interpret-mode grid walks
        its steps sequentially through full-buffer copies, so ANY pallas
        lowering is O(n^2) there; the jnp path is elementwise-linear.

    Both produce each client's unbatched byte stream bit-exactly (global
    counters make the tiling invisible — noise.tile_u01)."""

    @jax.custom_batching.custom_vmap
    def enc(x2d, key2, sigma):
        return K.compress_rng_pallas(x2d, key2, sigma, z=z,
                                     interpret=interpret)

    @enc.def_vmap
    def _batched(axis_size, in_batched, x2d, key2, sigma):
        n = axis_size
        if not in_batched[0]:
            x2d = jnp.broadcast_to(x2d[None], (n,) + x2d.shape)
        if not in_batched[1]:
            key2 = jnp.broadcast_to(key2[None], (n,) + key2.shape)
        if not in_batched[2]:
            sigma = jnp.broadcast_to(jnp.reshape(sigma, (1,)), (n,))
        rows = x2d.shape[1]
        key2 = key2.reshape(n, 2)
        sigma = sigma.reshape(n).astype(jnp.float32)
        if interpret:
            return _batched_encode_tiles_jnp(x2d, key2, sigma, z=z), True
        packed = K.compress_rng_pallas_batched(
            x2d.reshape(n * rows, K.COLS), key2, sigma, z=z,
            interpret=interpret)
        return packed.reshape(n, rows, K.LANE), True

    return enc


@partial(jax.jit, static_argnames=("z", "add_noise", "interpret"))
def zsign_encode_fused(x: jax.Array, key: jax.Array, sigma,
                       *, z: int, add_noise: bool = True,
                       interpret: bool | None = None) -> jax.Array:
    """Fused client encode with IN-KERNEL counter-based noise.

    x: any-shape float32; key: the client's PRNG key (typed or raw uint32
    pair). Each 8192-element grid tile derives its randomness from
    threefry2x32(key, global_counters) and writes Sign(x + sigma*xi_z) as
    wire bytes directly — no fp32 noise buffer in HBM, unlike
    ``zsign_compress`` which takes a dense noise input. Returns uint8 of
    ceil(x.size/8192)*1024 bytes (kernel tile padding, as zsign_compress).
    ``z`` must be Z_INF (uniform) or 1 (Gaussian); ``add_noise=False``
    (static sigma == 0, vanilla SignSGD) skips the PRNG entirely. ``sigma``
    may be traced (Plateau dynamic sigma; stosign's per-client norm) — a
    runtime 0 also degrades exactly to noise-free signs.
    """
    interpret = _interpret() if interpret is None else interpret
    x2d, _ = _pad_flat(x.astype(jnp.float32))
    k0, k1 = znoise.key_words(key)
    key2 = jnp.stack([k0, k1]).reshape(1, 2)
    enc = _rng_encode_vmappable(z if add_noise else None, interpret)
    packed = enc(x2d, key2, jnp.asarray(sigma, jnp.float32))
    return packed.reshape(-1)


@partial(jax.jit, static_argnames=("interpret",))
def sign_reduce(packed: jax.Array, weights: jax.Array,
                *, interpret: bool | None = None) -> jax.Array:
    """Fused weighted sign-reduce: (n_clients, n_bytes) u8 + (n_clients,)
    f32 -> (8*n_bytes,) f32 weighted sum of the +/-1 signs.

    ONE kernel launch for the whole client stack (clients folded into the
    grid, VMEM accumulator per output tile) — replaces the per-client-row
    vmap over ``zsign_decompress_sum``. Clients are padded to CLIENT_BLK
    with zero weight, bytes to the (ROWS_BLK * LANE) tile; both pads
    contribute exactly 0.
    """
    interpret = _interpret() if interpret is None else interpret
    n, nbytes = packed.shape
    bpad = (-nbytes) % (K.ROWS_BLK * K.LANE)
    cpad = (-n) % K.CLIENT_BLK
    if bpad or cpad:
        packed = jnp.pad(packed, ((0, cpad), (0, bpad)))
    w = weights.astype(jnp.float32)
    if cpad:
        w = jnp.pad(w, (0, cpad))
    p3 = packed.reshape(n + cpad, -1, K.LANE)
    s = K.sign_reduce_pallas(p3, w.reshape(-1, 1), interpret=interpret)
    return s.reshape(-1)[: nbytes * 8]


@partial(jax.jit, static_argnames=("n_coords", "interpret"))
def zsign_decompress_sum(packed: jax.Array, n_coords: int,
                         *, interpret: bool | None = None) -> jax.Array:
    """packed: (n_clients, n_bytes) uint8 -> (n_coords,) f32 sum of signs."""
    interpret = _interpret() if interpret is None else interpret
    n, nbytes = packed.shape
    pad = (-nbytes) % (K.ROWS_BLK * K.LANE)
    if pad:
        packed = jnp.pad(packed, ((0, 0), (0, pad)))
    p3 = packed.reshape(n, -1, K.LANE)
    s = K.unpack_sum_pallas(p3, interpret=interpret).reshape(-1)
    return s[:n_coords]
