from repro.kernels.efsign.ops import ef_sign_update  # noqa: F401
