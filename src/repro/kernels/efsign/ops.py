"""jit'd wrapper for the fused EF-SignSGD update kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.efsign import efsign as K

TILE = K.ROWS_BLK * K.COLS


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("interpret",))
def ef_sign_update(g: jax.Array, e: jax.Array, scale,
                   *, interpret: bool | None = None):
    """Fused EF step on arbitrary-shaped g/e. Returns (q, e_new)."""
    interpret = _interpret() if interpret is None else interpret
    shape = g.shape
    flat_g = g.astype(jnp.float32).reshape(-1)
    flat_e = e.astype(jnp.float32).reshape(-1)
    pad = (-flat_g.size) % TILE
    if pad:
        flat_g = jnp.pad(flat_g, (0, pad))
        flat_e = jnp.pad(flat_e, (0, pad))
    q, e_new = K.ef_update_pallas(flat_g.reshape(-1, K.COLS),
                                  flat_e.reshape(-1, K.COLS),
                                  jnp.asarray(scale), interpret=interpret)
    n = g.size
    return (q.reshape(-1)[:n].reshape(shape),
            e_new.reshape(-1)[:n].reshape(shape))
