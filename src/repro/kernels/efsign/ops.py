"""jit'd wrapper for the fused EF-SignSGD update kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.efsign import efsign as K

TILE = K.ROWS_BLK * K.COLS


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _ef_call(g: jax.Array, e: jax.Array, scale, interpret):
    flat_g = g.astype(jnp.float32).reshape(-1)
    flat_e = e.astype(jnp.float32).reshape(-1)
    pad = (-flat_g.size) % TILE
    if pad:
        flat_g = jnp.pad(flat_g, (0, pad))
        flat_e = jnp.pad(flat_e, (0, pad))
    return K.ef_update_pallas(flat_g.reshape(-1, K.COLS),
                              flat_e.reshape(-1, K.COLS),
                              jnp.asarray(scale), interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def ef_sign_update(g: jax.Array, e: jax.Array, scale,
                   *, interpret: bool | None = None):
    """Fused EF step on arbitrary-shaped g/e. Returns (q, e_new)."""
    interpret = _interpret() if interpret is None else interpret
    q, e_new, _ = _ef_call(g, e, scale, interpret)
    n = g.size
    return (q.reshape(-1)[:n].reshape(g.shape),
            e_new.reshape(-1)[:n].reshape(g.shape))


@partial(jax.jit, static_argnames=("interpret",))
def ef_sign_encode(g: jax.Array, e: jax.Array, scale,
                   *, interpret: bool | None = None):
    """Fused EF encode for the flat wire codec: one VMEM pass yields BOTH the
    bitpacked uint8 payload (tile-padded; zero pad packs as +1 bits, same as
    wire.pack_flat) and the new flat residual. Returns (packed, e_new)."""
    interpret = _interpret() if interpret is None else interpret
    _, e_new, packed = _ef_call(g, e, scale, interpret)
    n = g.size
    return packed.reshape(-1), e_new.reshape(-1)[:n].reshape(g.shape)
