"""Pure-jnp oracle for the fused EF-SignSGD update."""
import jax.numpy as jnp


def ef_sign_update_ref(g, e, scale):
    """p = g + e; q = scale * sign(p); e' = p - q. Returns (q, e')."""
    p = g + e
    q = scale * jnp.sign(p)
    return q, p - q
