"""Pure-jnp oracle for the fused EF-SignSGD update."""
import jax.numpy as jnp


def ef_sign_update_ref(g, e, scale):
    """p = g + e; q = scale * Sign(p); e' = p - q. Returns (q, e').

    Sign convention is ``p >= 0 -> +1`` (matching the bitpacked wire format
    of core/wire.pack_flat), so the residual accounts exactly for what the
    server decodes — including p == 0 coordinates.
    """
    p = g + e
    q = scale * jnp.where(p >= 0, jnp.float32(1), jnp.float32(-1))
    return q, p - q
