"""Pallas TPU kernel: fused EF-SignSGD compress + residual update.

One pass over HBM computes ALL THREE outputs of the error-feedback step —
q = scale*Sign(g+e), the new residual e' = g+e-q, and the bitpacked uint8
wire payload (bit j of byte i == Sign((g+e)[8i+j]) >= 0, the same
little-endian layout as kernels/zsign) — instead of the separate elementwise
+ pack passes the naive jnp formulation costs. Same VMEM tiling discipline
as kernels/zsign: (ROWS_BLK, 1024) fp32 tiles in, (ROWS_BLK, 128) uint8
payload tiles out.

Sign convention is ``p >= 0 -> +1`` (matching wire.pack_flat), NOT jnp.sign:
the residual must account exactly for what the server decodes from the
bitpacked payload, including p == 0 coordinates.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
PACK = 8
COLS = LANE * PACK
ROWS_BLK = 8


def _ef_kernel(g_ref, e_ref, s_ref, q_ref, eout_ref, p_ref):
    p = g_ref[...] + e_ref[...]
    r = p.shape[0]
    pm = jnp.where(p >= 0.0, jnp.float32(1), jnp.float32(-1))
    q = s_ref[0, 0] * pm
    q_ref[...] = q
    eout_ref[...] = p - q
    bits = (p >= 0.0).reshape(r, LANE, PACK).astype(jnp.uint8)
    weights = (jnp.uint8(1) << jnp.arange(PACK, dtype=jnp.uint8))
    p_ref[...] = jnp.sum(bits * weights, axis=-1, dtype=jnp.uint8)


def ef_update_pallas(g2d, e2d, scale, *, interpret: bool):
    """(rows, 1024) f32 x2 + scale -> (q, e_new, packed_u8[rows, 128])."""
    rows = g2d.shape[0]
    grid = (rows // ROWS_BLK,)
    return pl.pallas_call(
        _ef_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROWS_BLK, COLS), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_BLK, COLS), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((ROWS_BLK, COLS), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_BLK, COLS), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_BLK, LANE), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, COLS), jnp.float32),
            jax.ShapeDtypeStruct((rows, COLS), jnp.float32),
            jax.ShapeDtypeStruct((rows, LANE), jnp.uint8),
        ],
        interpret=interpret,
    )(g2d, e2d, scale.reshape(1, 1).astype(jnp.float32))
