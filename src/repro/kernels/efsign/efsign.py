"""Pallas TPU kernel: fused EF-SignSGD compress + residual update.

One pass over HBM computes BOTH outputs of the error-feedback step
(q = scale*sign(g+e) and the new residual e' = g+e-q), instead of the three
separate elementwise passes the naive jnp formulation costs. Same VMEM
tiling discipline as kernels/zsign: (ROWS_BLK, 1024) fp32 tiles.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

COLS = 1024
ROWS_BLK = 8


def _ef_kernel(g_ref, e_ref, s_ref, q_ref, eout_ref):
    p = g_ref[...] + e_ref[...]
    q = s_ref[0, 0] * jnp.sign(p)
    q_ref[...] = q
    eout_ref[...] = p - q


def ef_update_pallas(g2d, e2d, scale, *, interpret: bool):
    rows = g2d.shape[0]
    grid = (rows // ROWS_BLK,)
    return pl.pallas_call(
        _ef_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROWS_BLK, COLS), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_BLK, COLS), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((ROWS_BLK, COLS), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_BLK, COLS), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, COLS), jnp.float32),
            jax.ShapeDtypeStruct((rows, COLS), jnp.float32),
        ],
        interpret=interpret,
    )(g2d, e2d, scale.reshape(1, 1).astype(jnp.float32))
