"""Named, multi-slot per-client state substrate for compression pipelines.

A stateful pipeline stage declares its persistent buffers through
``state_spec(n_coords) -> tuple[StateSlot, ...]``.  Each :class:`StateSlot`
names one buffer, gives its per-client (or shared) shape, and fixes the
engine-facing semantics the round drivers rely on:

  scope="client"   one row per client.  The engine materializes the stacked
                   ``(client_groups, n_clients) + shape`` tree ONCE
                   (``fedavg.init_server_state``), slices it per shard under
                   every cohort plan (vmap / stream / host feed / async
                   buffering), and shards it along the cohort axis
                   (``launch/sharding.wire_state_specs``).
  scope="server"   one shared buffer, replicated across devices; updated in
                   the round tail (``_finish``) from the DECODED aggregate —
                   never from per-client payloads, so no dense
                   ``(n_clients, d)`` surface is ever needed.

  merge="keep"     the dead-client rule: a client that does not participate
                   in a round keeps its old rows bit-exactly (the engine
                   applies the participation mask with :func:`merge_rows`).

Slot NAMES are the keys of the state dict the pipeline passes to
``encode(key, flat, state)`` and returns from it: ``state["ef"]`` is the
error-feedback residual, ``state["cv"]`` the client control variate, and so
on.  Names must be unique across a pipeline's stages — a collision is a
build-time error (see ``Pipeline.__post_init__``), so composing two stages
that both claim a slot fails loudly instead of silently sharing a buffer.

This module is dependency-free inside the repo (jax/numpy only) so both
``core/`` and ``fed/`` layers can import it without cycles.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["StateSlot", "collect_slots", "init_tree", "merge_rows",
           "SCOPES", "MERGE_RULES"]

SCOPES = ("client", "server")
MERGE_RULES = ("keep",)


@dataclasses.dataclass(frozen=True)
class StateSlot:
    """One named persistent buffer of a stateful pipeline stage."""
    name: str
    shape: Tuple[int, ...]
    dtype: Any = jnp.float32
    scope: str = "client"
    merge: str = "keep"

    def __post_init__(self):
        if not self.name or not isinstance(self.name, str):
            raise ValueError(f"state slot needs a non-empty string name, "
                             f"got {self.name!r}")
        if self.scope not in SCOPES:
            raise ValueError(f"state slot {self.name!r}: scope must be one "
                             f"of {SCOPES}, got {self.scope!r}")
        if self.merge not in MERGE_RULES:
            raise ValueError(f"state slot {self.name!r}: merge must be one "
                             f"of {MERGE_RULES}, got {self.merge!r}")
        object.__setattr__(self, "shape", tuple(int(s) for s in self.shape))

    def zeros(self) -> jax.Array:
        return jnp.zeros(self.shape, self.dtype)


def collect_slots(stages, n_coords: int) -> Tuple[StateSlot, ...]:
    """All slots declared by ``stages`` (via ``state_spec``), in stage order.

    Raises ``ValueError`` on a slot-name collision — the loud failure that
    protects multi-state pipelines from two stages sharing a buffer.
    """
    slots, owner = [], {}
    for st in stages:
        spec = getattr(st, "state_spec", None)
        if spec is None:
            continue
        for s in spec(n_coords):
            if s.name in owner:
                raise ValueError(
                    f"state slot name collision: {s.name!r} declared by "
                    f"both {type(owner[s.name]).__name__} and "
                    f"{type(st).__name__} — slot names must be unique "
                    f"across a pipeline's stages")
            owner[s.name] = st
            slots.append(s)
    return tuple(slots)


def init_tree(slots, scope: str):
    """Zero-initialized ``{name: buffer}`` dict for one scope, or None when
    no slot has that scope (the engine's "stateless" marker)."""
    sel = {s.name: s.zeros() for s in slots if s.scope == scope}
    return sel or None


def merge_rows(new_state, old_state, mask: jax.Array):
    """Apply the merge="keep" dead-client rule over stacked state rows:
    rows of clients with ``mask > 0`` take the new value, dead clients keep
    their old rows bit-exactly.  ``mask`` has one entry per leading-axis row
    of every leaf."""
    def _merge(new, old):
        m = mask.reshape(mask.shape + (1,) * (new.ndim - mask.ndim))
        return jnp.where(m > 0, new, old)
    return jax.tree.map(_merge, new_state, old_state)
