"""Client participation: sampling, straggler mitigation, failure injection.

All participation decisions compile into a float mask (groups, n_clients)
consumed by the jitted round step — no recompilation when the live set
changes, which is the elasticity contract: a node failure is just a zero in
the mask, and the aggregator renormalizes by the live count.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class ParticipationSampler:
    """Uniform partial participation (paper §4.3: e.g. 100 of 3579 clients).

    ``over_provision`` implements deadline-based straggler mitigation: sample
    m = ceil(k * over_provision) clients, then keep only the k fastest
    (simulated by dropping the slowest m - k uniformly at random — on a real
    cluster the launcher fills the mask as acks arrive until the deadline).
    ``failure_rate`` injects node failures on top (fault-tolerance tests).
    """
    total_clients: int
    per_round: int
    over_provision: float = 1.0
    failure_rate: float = 0.0
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.RandomState(self.seed)

    def mask(self, layout: tuple) -> np.ndarray:
        """layout = (groups, n_clients) slots for this round."""
        groups, n = layout
        slots = groups * n
        m = min(slots, int(np.ceil(self.per_round * self.over_provision)))
        chosen = self._rng.choice(slots, size=m, replace=False)
        if m > self.per_round:  # straggler cut: keep the first k acks
            chosen = self._rng.permutation(chosen)[: self.per_round]
        mask = np.zeros(slots, np.float32)
        mask[chosen] = 1.0
        if self.failure_rate > 0:
            fail = self._rng.rand(slots) < self.failure_rate
            mask[fail] = 0.0
        if mask.sum() == 0:  # never lose a whole round
            mask[self._rng.randint(slots)] = 1.0
        return mask.reshape(groups, n)
