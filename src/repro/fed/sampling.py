"""Client participation: sampling, straggler mitigation, failure injection.

All participation decisions compile into a float mask (groups, n_clients)
consumed by the jitted round step — no recompilation when the live set
changes, which is the elasticity contract: a node failure is just a zero in
the mask, and the aggregator renormalizes by the live count.

Two samplers:

``ParticipationSampler``  the original uniform sampler over (groups,
    n_clients) slots — O(total) per round, dense mask, exactly 0/1.
``CohortSampler``         the massive-cohort sampler (10k-100k+ slots):
    the round's live set is an O(k) SORTED-INDEX + WEIGHT pair, never a
    dense permutation over all slots, and per-shard weight rows for the
    streaming round driver are sliced out by binary search
    (``shard_weights``). Three tiers: ``uniform`` (0/1 mask, the paper's
    §4.3 partial participation), ``importance`` (Gumbel top-k over client
    scores, 1/(k p_i) Horvitz-Thompson-style weights), and ``arrival``
    (independent Bernoulli(rate) arrivals, 1/rate weights — the buffered /
    asynchronous-arrival model). Only the uniform tier emits exact 0/1
    weights; the weighted tiers must run with
    ``RoundContext(weights_are_mask=False)`` — which also means the robust
    ``agg=vote|trimmed|median`` codec policies (membership-count
    aggregation, core/wire.py vote pair) are only available under uniform
    sampling: fractional weights are refused at trace time.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class ParticipationSampler:
    """Uniform partial participation (paper §4.3: e.g. 100 of 3579 clients).

    ``over_provision`` implements deadline-based straggler mitigation: sample
    m = ceil(k * over_provision) clients, then keep only the k fastest
    (simulated by dropping the slowest m - k uniformly at random — on a real
    cluster the launcher fills the mask as acks arrive until the deadline).
    ``failure_rate`` injects node failures on top (fault-tolerance tests).
    """
    total_clients: int
    per_round: int
    over_provision: float = 1.0
    failure_rate: float = 0.0
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.RandomState(self.seed)

    def mask(self, layout: tuple) -> np.ndarray:
        """layout = (groups, n_clients) slots for this round."""
        groups, n = layout
        slots = groups * n
        m = min(slots, int(np.ceil(self.per_round * self.over_provision)))
        chosen = self._rng.choice(slots, size=m, replace=False)
        if m > self.per_round:  # straggler cut: keep the first k acks
            chosen = self._rng.permutation(chosen)[: self.per_round]
        mask = np.zeros(slots, np.float32)
        mask[chosen] = 1.0
        if self.failure_rate > 0:
            fail = self._rng.rand(slots) < self.failure_rate
            mask[fail] = 0.0
        if mask.sum() == 0:  # never lose a whole round
            mask[self._rng.randint(slots)] = 1.0
        return mask.reshape(groups, n)


COHORT_TIERS = ("uniform", "importance", "arrival")


@dataclasses.dataclass
class CohortSampler:
    """Massive-cohort participation in O(per_round) space.

    ``sample()`` returns the round's live set as ``(idx, w)`` — sorted
    global client indices plus per-client aggregation weights — without
    ever materializing a dense mask or an O(total) permutation:

      uniform      k distinct clients, rejection-sampled when k << total
                   (O(k) expected) and Floyd-style otherwise; weights 1.0
                   (an exact 0/1 membership mask once densified).
      importance   Gumbel top-k over ``log(scores)``: the classic
                   weighted-without-replacement draw, one vectorized pass
                   over the scores. Weights 1/(k p_i) (p_i = normalized
                   score) so high-probability clients are down-weighted and
                   the weighted sum stays an unbiased mean estimate.
      arrival      every client arrives independently w.p. ``rate`` (the
                   asynchronous cross-device model): the arrival count is
                   one Binomial draw, the arrivals a uniform subset, and
                   weights 1/rate debias the random cohort size
                   (Horvitz-Thompson).

    ``shard_weights`` densifies one ``shard``-client slice of the weight
    vector by binary search over the sorted indices — O(log k + hits) per
    shard, so a 100k-slot round never allocates more than the slice the
    streaming round driver is about to consume. ``dense`` densifies the
    whole layout for the vmap path (still O(total) OUTPUT, but O(k)
    sampling work).
    """
    total_clients: int
    per_round: int
    tier: str = "uniform"
    #: per-client importance scores, shape (total_clients,) — importance tier
    scores: Optional[np.ndarray] = None
    #: per-round arrival probability — arrival tier
    rate: float = 0.1
    seed: int = 0

    def __post_init__(self):
        if self.tier not in COHORT_TIERS:
            raise ValueError(f"unknown cohort sampling tier {self.tier!r}; "
                             f"expected one of {COHORT_TIERS}")
        if not 0 < self.per_round <= self.total_clients:
            raise ValueError(f"per_round must be in [1, total_clients], got "
                             f"{self.per_round} of {self.total_clients}")
        if self.tier == "importance":
            if self.scores is None:
                raise ValueError("importance tier needs per-client scores")
            s = np.asarray(self.scores, np.float64)
            if s.shape != (self.total_clients,) or (s <= 0).any():
                raise ValueError("scores must be positive with shape "
                                 "(total_clients,)")
            self.scores = s
        if self.tier == "arrival" and not 0.0 < self.rate <= 1.0:
            raise ValueError(f"arrival rate must be in (0, 1], got "
                             f"{self.rate}")
        self._rng = np.random.RandomState(self.seed)

    def _uniform_indices(self, k: int) -> np.ndarray:
        total = self.total_clients
        if k >= total:
            return np.arange(total, dtype=np.int64)
        if k <= total // 64:
            # rejection sampling: expected < 2 draws per kept index at this
            # density — O(k), no O(total) permutation buffer
            chosen: set = set()
            while len(chosen) < k:
                need = int((k - len(chosen)) * 1.2) + 8
                chosen.update(self._rng.randint(0, total, need).tolist())
            return np.fromiter(chosen, np.int64, len(chosen))[:k]
        return self._rng.choice(total, size=k, replace=False).astype(np.int64)

    def sample(self) -> Tuple[np.ndarray, np.ndarray]:
        """-> (idx, w): sorted global client indices (int64, ascending) and
        their aggregation weights (float32), both of the live-count length.
        Never densifies over total_clients."""
        if self.tier == "uniform":
            idx = self._uniform_indices(self.per_round)
            w = np.ones(idx.size, np.float32)
        elif self.tier == "importance":
            p = self.scores / self.scores.sum()
            gumbel = -np.log(-np.log(
                self._rng.uniform(1e-12, 1.0, self.total_clients)))
            keys = np.log(p) + gumbel
            idx = np.argpartition(keys, -self.per_round)[-self.per_round:]
            idx = idx.astype(np.int64)
            w = (1.0 / (self.per_round * p[idx])).astype(np.float32)
        else:  # arrival
            k = int(self._rng.binomial(self.total_clients, self.rate))
            k = max(1, k)  # never lose a whole round
            idx = self._uniform_indices(k)
            w = np.full(idx.size, 1.0 / self.rate, np.float32)
        order = np.argsort(idx, kind="stable")
        return idx[order], w[order]

    def shard_weights(self, idx: np.ndarray, w: np.ndarray,
                      shard_idx: int, shard: int) -> np.ndarray:
        """Dense (shard,) f32 weight row for global slots
        [shard_idx * shard, (shard_idx + 1) * shard) — zeros for absent
        clients. O(log k + hits) via searchsorted on the sorted ``idx``."""
        lo = shard_idx * shard
        a, b = np.searchsorted(idx, [lo, lo + shard])
        row = np.zeros(shard, np.float32)
        row[idx[a:b] - lo] = w[a:b]
        return row

    def iter_shards(self, idx: np.ndarray, w: np.ndarray,
                    shard: int) -> Iterator[np.ndarray]:
        """Yield every shard's dense weight row in order (the streaming
        driver's host-side feed); the last shard is zero-padded past
        total_clients."""
        n_shards = -(-self.total_clients // shard)
        for s in range(n_shards):
            yield self.shard_weights(idx, w, s, shard)

    def device_partitions(self, idx: np.ndarray, w: np.ndarray, *,
                          shard: int, devices: int) -> Iterator[np.ndarray]:
        """Per-device weight blocks for the multi-device streaming round
        (``stream(devices=D)``): device d gets the same CONTIGUOUS slice of
        the global shard sequence the engine's shard_map partition assigns
        it — ceil(n_shards / devices) shards each, the trailing all-padding
        shards densified as zero rows. Yields ``devices`` arrays of shape
        (shards_per_device, shard), still O(k) sampling work + O(slice)
        output per device, so a host can stage each device's feed
        independently."""
        if devices < 1:
            raise ValueError(f"devices must be >= 1, got {devices}")
        n_shards = -(-self.total_clients // shard)
        n_shards = -(-n_shards // devices) * devices   # engine's device pad
        per = n_shards // devices
        for d in range(devices):
            yield np.stack([self.shard_weights(idx, w, s, shard)
                            for s in range(d * per, (d + 1) * per)])

    def partition_state_rows(self, cstate: dict, *, shard: int,
                             devices: int) -> Iterator[dict]:
        """Per-device blocks of the KEYED client-state tree (the stacked
        ``{slot: (groups, n_clients, ...)}`` of Pipeline.init_state — EF
        residuals, cv client variates), partitioned EXACTLY like
        ``device_partitions`` partitions the weight rows: device d gets the
        same contiguous shard slice, and padded slots wrap cyclically to
        the cohort's first rows (``slot % total_clients``) — the engine's
        own reshard rule, so a host can stage each device's state feed
        next to its weight feed without ever materializing the wrapped
        O(slots) copy for more than one device. Yields ``devices`` dicts of
        leaves shaped (shards_per_device, shard, ...)."""
        if devices < 1:
            raise ValueError(f"devices must be >= 1, got {devices}")
        total = self.total_clients
        n_shards = -(-total // shard)
        n_shards = -(-n_shards // devices) * devices   # engine's device pad
        per = n_shards // devices
        flat = {k: np.asarray(v).reshape((total,) + np.shape(v)[2:])
                for k, v in cstate.items()}
        for d in range(devices):
            sl = np.arange(d * per * shard, (d + 1) * per * shard) % total
            yield {k: v[sl].reshape((per, shard) + v.shape[1:])
                   for k, v in flat.items()}

    def dense(self, idx: np.ndarray, w: np.ndarray,
              layout: tuple) -> np.ndarray:
        """Full (groups, n_clients) weight mask for the engine's round-step
        signature (groups * n_clients slots must cover total_clients)."""
        groups, n = layout
        mask = np.zeros(groups * n, np.float32)
        mask[idx] = w
        return mask.reshape(groups, n)

    def mask(self, layout: tuple) -> np.ndarray:
        """ParticipationSampler-compatible convenience: one fresh sample,
        densified."""
        return self.dense(*self.sample(), layout)
