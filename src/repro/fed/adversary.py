"""Wire-level Byzantine fault injection for federated round drivers.

An :class:`Adversary` is a deterministic, jit-traceable corruption policy
applied at the two places a real attacker acts:

  * the PARTICIPATION mask (``drop_mask``) — mid-round dropout: scheduled
    clients that would have participated go dark, so their votes, loss
    contribution and state updates all vanish consistently;
  * the uint8 PAYLOAD stack (``corrupt``) — what a Byzantine client puts on
    the wire AFTER honest-looking local training: sign-flips, random byte
    corruption, or a colluding cohort that replaces its payloads with one
    shared adversarial pattern.

Corruption happens on the ENCODED wire bytes, after the client encode and
before aggregation/state masking — transit-level semantics. An EF client's
residual is therefore computed against what it MEANT to send (the honest
payload), exactly as a real man-in-the-middle or a malicious client lying
on the wire would leave it.

Determinism and plan-invariance: which clients are corrupt in a round
depends only on (global client index, round index, seed) — never on the
cohort plan — so the same attack hits the same clients bit-for-bit under
vmap, ``stream(shard=K)`` and ``stream(devices=D)``. The byte-corruption
randomness is counter-style (``fold_in(fold_in(key, round), client)``),
so it is shard- and device-placement-invariant too. Stream-padding slots
(index >= total clients) are never selected.

Spec grammar (the ``--adversary`` CLI flag / ``RoundContext.adversary``)::

    none
    sign_flip(f=4)                      # clients 0..3 send -sign(x)
    byte_corrupt(f=2,p=0.1)             # 2 clients, each byte hit w.p. 0.1
    collude(f=4)                        # 4 clients send ONE shared pattern
    dropout(f=8)                        # 8 would-be participants go dark
    sign_flip(f=4,every=2,start=10)     # rounds 10, 12, 14, ...
    sign_flip(f=4,rotate=true,seed=7)   # membership rotates each round

``f`` is the corrupt-cohort size; ``every``/``start`` schedule the attack
(active when ``round >= start`` and ``(round - start) % every == 0``);
``rotate`` slides the corrupt set by ``f`` slots per round (needs the
total-client bound the round engine supplies via :meth:`Adversary.bind`).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["Adversary", "parse_adversary", "ADVERSARY_KINDS"]

#: recognized attack kinds. "dropout" acts on the mask; the others on the
#: encoded payload stack.
ADVERSARY_KINDS = ("sign_flip", "byte_corrupt", "collude", "dropout")


@dataclasses.dataclass(frozen=True)
class Adversary:
    """One deterministic fault-injection policy (see module docstring)."""
    kind: str
    #: corrupt-cohort size (clients per active round)
    f: int = 1
    #: per-byte corruption probability (byte_corrupt only)
    p: float = 0.05
    #: attack fires every this many rounds ...
    every: int = 1
    #: ... starting at this round
    start: int = 0
    #: slide the corrupt set by f slots per round (else clients 0..f-1)
    rotate: bool = False
    #: PRNG seed for byte/collude payload randomness
    seed: int = 0
    #: total client slots — bound by the round engine (:meth:`bind`); the
    #: modulus for rotation and the guard against corrupting pad slots
    total: int = 0

    def __post_init__(self):
        if self.kind not in ADVERSARY_KINDS:
            raise ValueError(f"unknown adversary kind {self.kind!r}; "
                             f"expected one of {ADVERSARY_KINDS} or 'none'")
        if self.f < 1:
            raise ValueError(f"adversary needs f >= 1 corrupt clients, got "
                             f"f={self.f} (use 'none' for no attack)")
        if self.every < 1 or self.start < 0:
            raise ValueError(f"bad schedule: every={self.every} (>= 1), "
                             f"start={self.start} (>= 0)")
        if self.kind == "byte_corrupt" and not 0.0 < self.p <= 1.0:
            raise ValueError(f"byte_corrupt needs 0 < p <= 1, got {self.p}")

    # -- engine binding ------------------------------------------------------

    def bind(self, total: int) -> "Adversary":
        """Bind the deployment's total client-slot count (rotation modulus
        + pad-slot guard). Called once by ``fedavg.build_round_step``."""
        if total < 1:
            raise ValueError(f"total client slots must be >= 1, got {total}")
        if self.f >= max(total, 1) and self.kind != "dropout":
            # f >= n corrupts every client; allowed for dropout (the mask
            # guard keeps one live client) but meaningless for payload
            # attacks under any robust aggregator — refuse loudly
            raise ValueError(f"adversary f={self.f} corrupts every one of "
                             f"{total} client slots; robust aggregation "
                             f"requires f < n/2")
        return dataclasses.replace(self, total=total)

    # -- round-indexed selection --------------------------------------------

    def _selected(self, idx: jax.Array, round_idx: jax.Array) -> jax.Array:
        """Boolean per slot: is this GLOBAL client index corrupt this round?
        Deterministic in (idx, round_idx) only — plan/placement-invariant."""
        if self.total < 1:
            raise ValueError("adversary is unbound — the engine must call "
                             "bind(total_clients) before tracing")
        idx = idx.astype(jnp.int32)
        r = jnp.asarray(round_idx, jnp.int32)
        active = (r >= self.start) & ((r - self.start) % self.every == 0)
        if self.rotate:
            sel = (idx - r * self.f) % self.total < self.f
        else:
            sel = idx < self.f
        return sel & (idx < self.total) & active

    # -- the two injection hooks --------------------------------------------

    def drop_mask(self, mask: jax.Array, round_idx: jax.Array) -> jax.Array:
        """Mid-round dropout: zero scheduled slots out of the participation
        mask. Identity for payload-attack kinds. ``mask`` is the engine's
        full (groups, n_clients) slot mask; slot (g, i) has global index
        g * n_clients + i."""
        if self.kind != "dropout":
            return mask
        idx = jnp.arange(mask.size, dtype=jnp.int32).reshape(mask.shape)
        return jnp.where(self._selected(idx, round_idx),
                         jnp.zeros_like(mask), mask)

    def corrupt(self, payload, idx: jax.Array, round_idx: jax.Array):
        """Apply the payload attack to one group's encoded payload stack.

        ``payload`` is whatever the codec put on the wire, with a leading
        client axis matching ``idx`` (the GLOBAL indices of those clients):
        a bitpacked (n, n_bytes) uint8 array, a {"packed", "scale"} dict, a
        COO {"values", "indices"} dict, or a dense (n, d) f32 stack.
        Identity for the dropout kind (that attack acts on the mask).
        """
        if self.kind == "dropout":
            return payload
        sel = self._selected(idx, round_idx)
        if isinstance(payload, dict):
            if "packed" in payload:
                out = dict(payload)
                out["packed"] = self._corrupt_packed(payload["packed"], sel,
                                                     idx, round_idx)
                return out
            if "values" in payload:
                if self.kind != "sign_flip":
                    raise ValueError(
                        f"adversary kind {self.kind!r} targets the bitpacked "
                        f"uint8 wire; the sparse COO payload only supports "
                        f"sign_flip (value negation)")
                out = dict(payload)
                out["values"] = jnp.where(sel[:, None], -payload["values"],
                                          payload["values"])
                return out
            raise ValueError(f"unrecognized payload dict keys "
                             f"{sorted(payload)} for adversary injection")
        arr = jnp.asarray(payload)
        if arr.dtype == jnp.uint8:
            return self._corrupt_packed(arr, sel, idx, round_idx)
        if self.kind != "sign_flip":
            raise ValueError(
                f"adversary kind {self.kind!r} targets the bitpacked uint8 "
                f"wire; dense f32 payloads only support sign_flip")
        return jnp.where(sel.reshape((-1,) + (1,) * (arr.ndim - 1)),
                         -arr, arr)

    def _corrupt_packed(self, packed: jax.Array, sel: jax.Array,
                        idx: jax.Array, round_idx: jax.Array) -> jax.Array:
        u8 = jnp.uint8
        n_bytes = packed.shape[-1]
        if self.kind == "sign_flip":
            # every sign inverted: XOR the whole bitfield
            return jnp.where(sel[:, None], packed ^ u8(0xFF), packed)
        rkey = jax.random.fold_in(jax.random.PRNGKey(self.seed),
                                  jnp.asarray(round_idx, jnp.int32))
        if self.kind == "collude":
            # the coordinated attack: every colluder transmits the SAME
            # adversarially chosen direction, drawn fresh each round
            patt = jax.random.randint(rkey, (n_bytes,), 0, 256, dtype=u8)
            return jnp.where(sel[:, None], patt[None, :], packed)
        # byte_corrupt: per-client counter-derived randomness, so the hit
        # pattern is identical under any shard/device partition
        def row(i):
            kb, kv = jax.random.split(
                jax.random.fold_in(rkey, i.astype(jnp.int32)))
            hit = jax.random.bernoulli(kb, self.p, (n_bytes,))
            rnd = jax.random.randint(kv, (n_bytes,), 0, 256, dtype=u8)
            return hit, rnd
        hit, rnd = jax.vmap(row)(idx)
        return jnp.where(sel[:, None] & hit, rnd, packed)


def parse_adversary(spec: str):
    """Adversary spec string -> :class:`Adversary`, or None for "none".

    Grammar: ``kind`` or ``kind(k=v,...)`` with kinds sign_flip |
    byte_corrupt | collude | dropout and args f=, p=, every=, start=,
    rotate=, seed= (see module docstring for semantics and examples).
    """
    s = spec.strip()
    if s in ("", "none"):
        return None
    if "(" not in s:
        return Adversary(kind=s)
    if not s.endswith(")"):
        raise ValueError(f"malformed adversary spec {spec!r}")
    kind, args = s[:-1].split("(", 1)
    kw = {}
    for part in filter(None, (p.strip() for p in args.split(","))):
        if "=" not in part:
            raise ValueError(f"adversary argument {part!r} in {spec!r} must "
                             f"be key=value")
        k, v = (x.strip() for x in part.split("=", 1))
        if k not in ("f", "p", "every", "start", "rotate", "seed"):
            raise ValueError(f"unknown adversary argument {k!r} in {spec!r}; "
                             f"expected f=, p=, every=, start=, rotate= or "
                             f"seed=")
        if k == "rotate":
            if v.lower() not in ("true", "false", "1", "0"):
                raise ValueError(f"rotate must be true/false, got {v!r}")
            kw[k] = v.lower() in ("true", "1")
        elif k == "p":
            kw[k] = float(v)
        else:
            try:
                kw[k] = int(v)
            except ValueError:
                raise ValueError(f"adversary argument {part!r} in {spec!r} "
                                 f"must be an integer") from None
    return Adversary(kind=kind.strip(), **kw)
