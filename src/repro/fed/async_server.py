"""Async, deadline-based federated round driver (straggler tolerance).

The synchronous round is a barrier: decode waits for the slowest live
client. This module makes "when does the round close" a first-class policy
(:class:`repro.core.context.RoundModePolicy`, spec
``round_mode="async(deadline=T[,min_clients=M][,staleness=...])"``): a
host-side event loop walks the cohort in shard order, folding each
arriving payload into the wire accumulator immediately — the same
``Pipeline.aggregate(..., acc=...)`` fold hooks the streaming engine
uses — and closes the round at a participation deadline.

Simulated time. Client wall-clock latency comes from a deterministic
:class:`LatencyModel` (the ``RoundContext.latency`` spec): per
(seed, round) the model draws one latency per client; failures draw +inf.
One round's compute window is the time unit, so a client with latency 2.7
under deadline 1.0 reports during round r+2. The partition of a round's
cohort:

  * ON TIME  (latency <= effective deadline): payload folds into THIS
    round at its mask weight — indistinguishable from the sync round.
  * LATE     (finite latency past the deadline): the client still
    computes — against the params of the round it was scheduled in — and
    its payload is buffered host-side, arriving in round r + s
    (s = ceil(latency / deadline) - 1, at least 1) where it folds at the
    buffered-staleness weight ``RoundModePolicy.stale_weight(s)``. A zero
    stale weight means the client is dropped instead (it never computes).
  * DEAD     (mask 0, adversary dropout, or a latency-model failure):
    ordinary dead-client mask semantics — no compute, residuals frozen.

``min_clients=M`` extends the close past the deadline until the M fastest
live payloads have arrived (the classic buffered-async guard against
near-empty rounds).

THE invariant (pinned in tests/test_async_server.py): with zero simulated
latency and a deadline covering every client, the async round is
BIT-IDENTICAL — params, residuals, metrics — to the sync
``stream(feed=host)`` round (itself pinned bit-identical to the device
stream and vmap plans). This falls out of construction, not tolerance
windows: the async driver runs the same per-shard computation as the sync
host driver (same global-index client keys, same shard slices, same
partition-invariant ``wire.SignFoldAcc`` fold), plus an empty pending
buffer.

Adversaries compose: ``RoundContext.adversary`` dropout hits the mask
before the latency partition, and payload corruption is injected inside
``group_encode`` by global client index + round — identical bytes under
the sync and async drivers.

An async round step is a Python loop (host-side event queue + numpy
buffers). It must NOT be wrapped in jax.jit, and its late-payload queue
lives in the step closure — drive ONE training run per built step (build
another step for a second run; reusing one step across interleaved runs
would cross their queues). Entry point: ``fedavg.build_round_step``
dispatches here when the context says ``round_mode="async(...)"``; this
module is never imported otherwise.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import wire
from repro.core import noise as znoise
from repro.core.context import RoundModePolicy

#: latency model kinds (RoundContext.latency spec heads)
LATENCY_KINDS = ("zero", "const", "linear", "lognormal", "pareto")


@dataclasses.dataclass(frozen=True)
class LatencyModel:
    """Deterministic simulated client latency + failure draw.

    One draw per (seed, round, client); the time unit is one round's
    compute window (the async deadline is expressed in the same unit).

      zero                          every client reports instantly
      const(t=T)                    every client takes T
      linear(base=B,step=S)         client i takes B + S*i (closed-form —
                                    the exactness-test workhorse)
      lognormal(median=M,sigma=S)   heavy tail: M * exp(S * N(0,1))
      pareto(xm=X,alpha=A)          heavier tail: classic Pareto(xm, alpha)

    ``fail=P`` gives every client an independent per-round probability of
    never reporting (latency +inf -> dead-client semantics). All draws
    come from one numpy RandomState seeded by (seed, round), so the same
    spec replays the same stragglers on any machine.
    """
    kind: str = "zero"
    t: float = 0.0
    base: float = 0.0
    step: float = 0.0
    median: float = 1.0
    sigma: float = 1.0
    xm: float = 1.0
    alpha: float = 1.5
    fail: float = 0.0
    seed: int = 0

    def __post_init__(self):
        if self.kind not in LATENCY_KINDS:
            raise ValueError(f"unknown latency kind {self.kind!r}; expected "
                             f"one of {LATENCY_KINDS}")
        if not 0.0 <= self.fail < 1.0:
            raise ValueError(f"latency fail= must be in [0, 1), got "
                             f"{self.fail!r}")
        if self.kind == "pareto" and self.alpha <= 0.0:
            raise ValueError("pareto latency needs alpha > 0")

    def sample(self, round_idx: int, n: int) -> np.ndarray:
        """(n,) float64 latencies for this round; failed clients get +inf."""
        rs = np.random.RandomState(
            (self.seed * 1000003 + int(round_idx) * 7919 + 17) % (1 << 32))
        if self.kind == "zero":
            lat = np.zeros(n)
        elif self.kind == "const":
            lat = np.full(n, float(self.t))
        elif self.kind == "linear":
            lat = self.base + self.step * np.arange(n, dtype=np.float64)
        elif self.kind == "lognormal":
            lat = self.median * np.exp(self.sigma * rs.randn(n))
        else:  # pareto
            lat = self.xm * (1.0 + rs.pareto(self.alpha, n))
        if self.fail > 0.0:
            lat = np.where(rs.rand(n) < self.fail, np.inf, lat)
        return lat


def parse_latency(spec) -> LatencyModel:
    """``zero | const(t=T) | linear(base=B,step=S) |
    lognormal(median=M,sigma=S) | pareto(xm=X,alpha=A)`` with optional
    ``fail=P`` / ``seed=N`` arguments -> :class:`LatencyModel`."""
    if isinstance(spec, LatencyModel):
        return spec
    s = spec.strip()
    if "(" not in s:
        return LatencyModel(kind=s)
    if not s.endswith(")"):
        raise ValueError(f"malformed latency spec {spec!r}")
    kind, args = s[:-1].split("(", 1)
    kw = {}
    for part in filter(None, (p.strip() for p in args.split(","))):
        if "=" not in part:
            raise ValueError(f"latency argument {part!r} in {spec!r} must "
                             f"be key=value")
        k, v = (t.strip() for t in part.split("=", 1))
        if k == "seed":
            kw[k] = int(v)
        elif k in ("t", "base", "step", "median", "sigma", "xm", "alpha",
                   "fail"):
            kw[k] = float(v)
        else:
            raise ValueError(f"unknown latency argument {k!r} in {spec!r}")
    return LatencyModel(kind=kind.strip(), **kw)


def staleness_rounds(lat: np.ndarray, deadline: float) -> np.ndarray:
    """Closed-form arrival lag: a payload with latency ``lat`` computed in
    round r arrives in round r + s, s = ceil(lat / deadline) - 1, clamped
    to >= 1 — anything past the deadline waits for at least the NEXT fold
    opportunity. Vectorized; +inf stays +inf."""
    with np.errstate(invalid="ignore"):
        s = np.ceil(np.asarray(lat, np.float64) / float(deadline)) - 1.0
    return np.maximum(s, 1.0)


def partition_round(policy: RoundModePolicy, lat: np.ndarray,
                    live: np.ndarray):
    """Split one round's cohort by the deadline law.

    Returns ``(on_time, stale_s, stale_w, close_time)``: boolean on-time
    selector, per-client integer arrival lag (0 where not late-folding),
    per-client stale fold weight (0 where dropped), and the simulated
    round close time — the last on-time arrival, or the effective deadline
    when someone is late (``min_clients`` may have extended it). All
    numpy, all deterministic.
    """
    lat = np.asarray(lat, np.float64)
    live = np.asarray(live, bool)
    finite = live & np.isfinite(lat)
    eff_t = float(policy.deadline)
    if policy.min_clients > 0 and np.any(finite):
        have = int(np.sum(finite & (lat <= eff_t)))
        if have < policy.min_clients:
            cand = np.sort(lat[finite])
            kth = cand[min(policy.min_clients, cand.size) - 1]
            eff_t = max(eff_t, float(kth))
    on_time = finite & (lat <= eff_t)
    late = finite & ~on_time
    s = np.zeros(lat.shape, np.int64)
    w = np.zeros(lat.shape, np.float64)
    if np.any(late):
        s_late = staleness_rounds(lat[late], policy.deadline).astype(np.int64)
        w_late = np.array([policy.stale_weight(int(si)) for si in s_late])
        s[late] = np.where(w_late > 0.0, s_late, 0)
        w[late] = w_late
    if np.any(late) or not np.any(on_time):
        close = eff_t
    else:
        close = float(np.max(lat[on_time]))
    return on_time, s, w, close


def simulate_close_times(policy: RoundModePolicy, model: LatencyModel,
                         rounds: int, total: int) -> np.ndarray:
    """(rounds, 2) simulated round close times: column 0 the async close
    (:func:`partition_round`), column 1 the sync barrier — the slowest
    FINITE live latency (a sync round with a failed client never closes,
    so failures are excluded from the barrier). Feeds the benchmark's
    p50/p90 round-latency rows."""
    out = np.empty((rounds, 2))
    live = np.ones(total, bool)
    for r in range(rounds):
        lat = model.sample(r, total)
        out[r, 0] = partition_round(policy, lat, live)[3]
        finite = np.isfinite(lat)
        out[r, 1] = float(np.max(lat[finite])) if np.any(finite) else 0.0
    return out


def build_async_round_step(*, policy: RoundModePolicy, latency_spec,
                           compressor, cfg, round_math, finish,
                           constrain_wire, cohort_policy, adversary,
                           total: int):
    """Assemble the async round driver. Called ONLY from
    ``fedavg.build_round_step`` (which owns context resolution, the round
    math, and the ``_finish`` decode closure); every argument after
    ``policy``/``latency_spec`` is one of that builder's internals, handed
    over so the async driver runs the IDENTICAL per-shard computation.

    Returns ``async_round_step(state, batch, mask) -> (state, metrics)`` —
    a host Python loop (do not jit)."""
    from repro.core import fedavg  # deferred: breaks the core<->fed cycle

    latency = parse_latency(latency_spec)
    codec = getattr(compressor, "codec", compressor)
    if policy.staleness == "poly" and getattr(codec, "weights_are_mask",
                                              False):
        raise ValueError(
            "staleness=poly(...) folds FRACTIONAL stale weights, which "
            "breaks the static weights_are_mask 0/1 contract (and the "
            "vote/popcount aggregation laws built on it). Use "
            "staleness=cutoff(s) with this pipeline, or drop "
            "weights_are_mask.")
    shard_fns = {}
    #: host-side event queue: arrival round -> list of
    #: (compute_round, client_id, fold_weight, payload_row); rows are
    #: numpy trees, replayed in (compute_round, client_id) order
    pending = {}

    def _shard_fn(spec, shard):
        # the sync host driver's jitted per-shard kernel, generalized two
        # ways: a FOLD weight vector separate from the compute mask (late
        # clients compute at mask weight but fold in a later round), and
        # the encoded payload stack as an extra output so late rows can be
        # sliced into the host-side queue
        key = (shard, spec.n_coords)
        if key not in shard_fns:
            def fn(params, sub, sigma, server, round_idx, s_idx, batch_s,
                   cstate_s, mask_s, fold_w_s, acc, loss_acc):
                keys_s = znoise.client_keys(sub, s_idx * jnp.uint32(shard),
                                            shard)
                idx_s = (s_idx.astype(jnp.int32) * shard
                         + jnp.arange(shard, dtype=jnp.int32))
                enc, new_cstate_s, loss_s = round_math.group_encode(
                    spec, params, batch_s, keys_s, cstate_s, mask_s, sigma,
                    idx_s, round_idx, server)
                acc = compressor.aggregate(enc, fold_w_s, spec.n_coords,
                                           acc=acc)
                if not isinstance(acc, wire.SignFoldAcc):
                    acc = constrain_wire(acc)
                return acc, loss_acc + loss_s, new_cstate_s, enc
            shard_fns[key] = jax.jit(fn)
        return shard_fns[key]

    def async_round_step(state, batch, mask):
        """Async round driver: shard walk + deadline fold + stale-payload
        queue. Python loop — do NOT wrap in jax.jit."""
        spec = wire.tree_spec(state.params)
        plan = fedavg.resolve_cohort(cohort_policy, total, spec.n_coords,
                                     None)
        shard = plan.shard if plan.mode == "stream" else total
        n_shards = -(-total // shard)
        rng, sub = jax.random.split(state.rng)
        sigma = state.sigma
        r = int(state.round)
        stateful = state.comp_state is not None

        mask_np = np.asarray(mask, np.float32)
        if adversary is not None:
            mask_np = np.asarray(adversary.drop_mask(
                jnp.asarray(mask_np, jnp.float32), state.round))
        flat_mask = mask_np.reshape(total)

        lat = latency.sample(r, total)
        on_time, stale_s, stale_w, _ = partition_round(
            policy, lat, flat_mask > 0.0)
        # the compute mask gates the client step + residual update (late
        # clients DO compute, against this round's params); the fold
        # weight keeps only the on-time payloads in this round's
        # accumulator. Zero latency makes the two vectors equal — and the
        # shard pass below byte-identical to the sync host driver's.
        computes = on_time | (stale_w > 0.0)
        compute_mask = (flat_mask * computes).astype(np.float32)
        fold_w = (flat_mask * on_time).astype(np.float32)
        late_ids = np.nonzero((stale_w > 0.0) & ~on_time
                              & (flat_mask > 0.0))[0]

        gen = fedavg.iter_shards(batch, compute_mask.reshape(mask_np.shape),
                                 state.comp_state, shard=shard, total=total)
        slots = n_shards * shard
        fold_w_pad = np.zeros(slots, np.float32)
        fold_w_pad[:total] = fold_w
        cur = jax.device_put(next(gen))
        enc_shape = jax.eval_shape(
            lambda b, k, c, m: round_math.group_encode(
                spec, state.params, b, k, c, m, sigma,
                server=state.comp_server)[0],
            cur[1], znoise.client_keys(sub, 0, shard), cur[2], cur[3])
        acc = (compressor.fold_init(enc_shape)
               if hasattr(compressor, "fold_init") else None)
        if acc is None:
            agg_shape = jax.eval_shape(
                lambda e, m: compressor.aggregate(e, m, spec.n_coords),
                enc_shape, cur[3])
            acc = jnp.zeros(agg_shape.shape, agg_shape.dtype)
        loss_sum = jnp.zeros(())
        fn = _shard_fn(spec, shard)
        rows_host, prev_rows = [], None
        for s_i in range(n_shards):
            # double buffer, exactly as the sync host driver: upload shard
            # s+1 before launching shard s, drain shard s-1's state rows
            # while shard s computes
            nxt = jax.device_put(next(gen)) if s_i + 1 < n_shards else None
            w_s = jnp.asarray(fold_w_pad[s_i * shard:(s_i + 1) * shard])
            acc, loss_sum, rows, enc = fn(state.params, sub, sigma,
                                          state.comp_server, state.round,
                                          *cur, w_s, acc, loss_sum)
            if stateful and prev_rows is not None:
                rows_host.append(jax.tree.map(np.asarray, prev_rows))
            prev_rows = rows
            # queue this shard's late payload rows for their arrival round
            # (each client id < total owns exactly one non-pad slot)
            lo = s_i * shard
            for cid in late_ids[(late_ids >= lo) & (late_ids < lo + shard)]:
                row = jax.tree.map(lambda x: np.asarray(x[int(cid) - lo]),
                                   enc)
                arrival = r + int(stale_s[cid])
                pending.setdefault(arrival, []).append(
                    (r, int(cid), float(flat_mask[cid] * stale_w[cid]),
                     row))
            cur = nxt

        # fold the stale payloads ARRIVING this round, in deterministic
        # (compute_round, client_id) order, each at its staleness weight
        stale_weight_sum = 0.0
        for _, _, w, row in sorted(pending.pop(r, []),
                                   key=lambda e: (e[0], e[1])):
            stacked = jax.tree.map(lambda x: jnp.asarray(x)[None], row)
            acc = compressor.aggregate(stacked,
                                       jnp.asarray([w], jnp.float32),
                                       spec.n_coords, acc=acc)
            if not isinstance(acc, wire.SignFoldAcc):
                acc = constrain_wire(acc)
            stale_weight_sum += w
        if hasattr(compressor, "fold_finalize"):
            acc = constrain_wire(compressor.fold_finalize(acc)) \
                if isinstance(acc, wire.SignFoldAcc) else acc

        new_cstate = None
        if stateful:
            rows_host.append(jax.tree.map(np.asarray, prev_rows))
            stacked = jax.tree.map(lambda *rs: np.concatenate(rs, axis=0),
                                   *rows_host)
            new_cstate = jax.tree.map(
                lambda x: x[:total].reshape(
                    (cfg.client_groups, cfg.n_clients) + x.shape[1:]),
                stacked)

        # the effective participation of the round: on-time mask weights
        # plus the stale weights folded in — _finish divides the decoded
        # mean by its sum, exactly the total weight the accumulator
        # carries. (The stale total rides on slot 0; _finish only reduces
        # the vector.) The loss metric instead covers every client that
        # COMPUTED this round, late ones included — it measures this
        # round's params, not this round's fold.
        eff_w = fold_w.copy()
        eff_w[0] += np.float32(stale_weight_sum)
        eff_mask = jnp.asarray(eff_w.reshape(mask_np.shape))
        return finish(state, spec, rng, sigma, acc, new_cstate, loss_sum,
                      eff_mask, plan.shard)

    return async_round_step
