"""End-to-end federated LM training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2_0_5b --reduced \
        --rounds 100 --clients 4 --local-steps 2 --compressor zsign \
        --ckpt-dir /tmp/ckpt

Production behavior in one binary: builds the model from the arch registry,
runs z-SignFedAvg rounds on a deterministic token stream, samples partial
participation with straggler over-provisioning, adapts sigma with the Plateau
criterion, checkpoints atomically every ``--save-every`` rounds and
self-resumes from the newest valid checkpoint on restart.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs.common import get_arch
from repro.core import compression, fedavg
from repro.core.plateau import PlateauController
from repro.data.synthetic import TokenStream
from repro.fed.sampling import ParticipationSampler
from repro.models.api import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--groups", type=int, default=1)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--micro-batch", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--compressor", default="zsign",
                    choices=list(compression.available()))
    ap.add_argument("--pipeline", default=None, metavar="SPEC",
                    help="compression pipeline spec string, overriding "
                         "--compressor and its kwargs — e.g. "
                         "'zsign(z=1,sigma=0.01)', 'ef|topk(frac=0.01)', "
                         "'dp(clip=1.0,eps=2.0)|zsign_packed', or compressed "
                         "SCAFFOLD control variates 'cv|zsign_packed' "
                         "(grammar: docs/API.md)")
    ap.add_argument("--agg-backend", default="auto",
                    choices=list(compression.AGG_BACKENDS),
                    help="sign-family server aggregation backend "
                         "(auto = Pallas kernel on TPU, bit-sliced jnp "
                         "elsewhere)")
    ap.add_argument("--encode-backend", default="auto",
                    choices=list(compression.ENCODE_BACKENDS),
                    help="sign-family client encode backend (auto = in-kernel"
                         " counter noise on TPU, fused jnp elsewhere; "
                         "reference = dense jax.random draw)")
    ap.add_argument("--cohort", default="auto",
                    help="cohort execution policy: 'auto' (stream only when "
                         "the round is large), 'vmap', or 'stream(shard=K|"
                         "auto[,unroll=U][,devices=D|auto][,feed=device|"
                         "host])' — stream runs client shards of K through "
                         "the fused encode under a scan, carrying only the "
                         "reduced wire accumulator; devices=D splits the "
                         "shard sequence over a D-device 'clients' mesh "
                         "with one O(d) psum; feed=host double-buffers "
                         "shards from host memory (grammar: docs/API.md)")
    ap.add_argument("--adversary", default="none", metavar="SPEC",
                    help="wire-level fault-injection policy: 'none', "
                         "'sign_flip(f=4)', 'byte_corrupt(f=2,p=0.1)', "
                         "'collude(f=4,rotate=true)', 'dropout(f=8)', with "
                         "optional every=/start= scheduling — applied to the "
                         "encoded payload stack (or the participation mask) "
                         "under every cohort plan (grammar: "
                         "src/repro/fed/adversary.py, docs/API.md)")
    ap.add_argument("--round-mode", default="sync", metavar="SPEC",
                    help="round execution mode: 'sync' (barrier round) or "
                         "'async(deadline=T[,min_clients=M][,staleness="
                         "none|poly(a)|cutoff(s)])' — deadline-fold round: "
                         "on-time payloads fold now, late ones buffer and "
                         "fold s rounds later at the staleness weight, "
                         "failures get dead-client mask semantics "
                         "(grammar: docs/API.md)")
    ap.add_argument("--latency", default="zero", metavar="SPEC",
                    help="simulated client latency for async rounds: 'zero',"
                         " 'const(t=T)', 'linear(base=B,step=S)', "
                         "'lognormal(median=M,sigma=S)', "
                         "'pareto(xm=X,alpha=A)', each with optional "
                         "fail=P / seed=N (src/repro/fed/async_server.py)")
    ap.add_argument("--debug-wire", action="store_true",
                    help="runtime-verify the 0/1 mask membership contract "
                         "before every popcount reduce (checkify-wrapped "
                         "round step; also via REPRO_DEBUG_WIRE=1)")
    ap.add_argument("--z", type=int, default=1, help="1=Gaussian, 0=uniform")
    ap.add_argument("--sigma", type=float, default=0.01,
                    help="z-sign noise scale / dpgauss noise stddev")
    ap.add_argument("--qsgd-s", type=int, default=1,
                    help="QSGD quantization levels")
    ap.add_argument("--topk-frac", type=float, default=0.01,
                    help="top-k kept fraction")
    ap.add_argument("--client-lr", type=float, default=0.05)
    ap.add_argument("--server-lr", type=float, default=0.5)
    ap.add_argument("--participation", type=float, default=1.0)
    ap.add_argument("--over-provision", type=float, default=1.0)
    ap.add_argument("--failure-rate", type=float, default=0.0)
    ap.add_argument("--plateau", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=20)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    if args.reduced:
        arch = arch.reduced()
    bundle = build_model(arch.model)

    if args.pipeline:
        comp = compression.Pipeline(args.pipeline)
    else:
        # legacy per-name kwargs -> the equivalent pipeline (shim-free)
        comp = {
            "zsign": lambda: compression.ZSignCompressor(
                z=args.z, sigma=args.sigma),
            "zsign_packed": lambda: compression.PackedZSignCompressor(
                z=args.z, sigma=args.sigma),
            "dpgauss": lambda: compression.DPGaussianCompressor(
                sigma=args.sigma),
            "qsgd": lambda: compression.QSGDCompressor(s=args.qsgd_s),
            "topk": lambda: compression.TopKCompressor(frac=args.topk_frac),
            "efsign": compression.EFSignCompressor,
            "stosign": compression.StoSignCompressor,
            "identity": compression.Compressor,
        }[args.compressor]()
    cfg = fedavg.FedConfig(n_clients=args.clients, client_groups=args.groups,
                           local_steps=args.local_steps,
                           client_lr=args.client_lr, server_lr=args.server_lr)
    # ONE typed deployment policy for the round step (core/context.py):
    # CLI backend selectors, the Plateau dynamic-sigma flag, and
    # weights_are_mask=True — the ParticipationSampler below produces exact
    # 0/1 membership masks, so the popcount aggregation specialization is
    # safe. donate_state: params + opt state + residual buffers update in
    # place on device instead of being copied every round.
    ctx_kw = dict(agg_backend=args.agg_backend,
                  encode_backend=args.encode_backend,
                  weights_are_mask=True,
                  dynamic_sigma=args.plateau,
                  cohort=args.cohort,
                  adversary=args.adversary,
                  round_mode=args.round_mode,
                  latency=args.latency)
    if args.debug_wire:  # else keep the REPRO_DEBUG_WIRE env default
        ctx_kw["debug_wire"] = True
    ctx = fedavg.RoundContext(**ctx_kw)
    host_loop = (fedavg.CohortPolicy.parse(args.cohort).feed == "host"
                 or fedavg.RoundModePolicy.parse(args.round_mode).mode
                 == "async")
    if ctx.debug_wire and host_loop:
        raise SystemExit("--debug-wire is not supported on stream(feed=host) "
                         "or async rounds: these host-loop drivers jit "
                         "per-shard kernels internally and cannot "
                         "functionalize the membership check")
    step = fedavg.build_round_step(bundle.loss_fn, comp, cfg, ctx)
    checked = None
    if not host_loop:
        if ctx.debug_wire:
            # debug mode refuses to run unchecked: the membership check is a
            # checkify.check, so the jitted step must be functionalized and
            # its error explicitly thrown each round
            from jax.experimental import checkify
            checked = checkify.checkify(jax.jit(step))
        else:
            step = jax.jit(step,
                           donate_argnums=(0,) if ctx.donate_state else ())
    # else: stream(feed=host) returns a Python-loop driver that device_puts
    # one shard at a time — it must NOT be jitted (and state donation is
    # meaningless for it; the jitted PER-SHARD kernel is cached inside)

    params = bundle.init(jax.random.PRNGKey(0))
    n_params = sum(p.size for p in jax.tree_util.tree_leaves(params))
    state = fedavg.init_server_state(params, cfg, comp, jax.random.PRNGKey(1),
                                     sigma0=args.sigma)
    start_round = 0
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if mgr:
        r, restored = mgr.restore_latest(state._asdict())
        if restored is not None:
            state = fedavg.ServerState(**restored)
            start_round = r
            print(f"# resumed from checkpoint at round {r}")

    stream = TokenStream(vocab=arch.model.vocab)
    total = args.groups * args.clients
    sampler = ParticipationSampler(
        total_clients=total,
        per_round=max(1, int(total * args.participation)),
        over_provision=args.over_provision, failure_rate=args.failure_rate)
    plateau = (PlateauController(sigma_init=args.sigma,
                                 sigma_bound=args.sigma * 100, kappa=10)
               if args.plateau else None)

    layout = (args.groups, args.clients, args.local_steps, args.micro_batch)
    per_step = bundle.train_batch_spec(args.micro_batch, args.seq_len)
    wf = comp.wire_format()
    print(f"# arch={arch.model.name} params={n_params:,} "
          f"compressor={comp.name} wire={wf.layout}/{wf.dtype} "
          f"({wf.bits_per_coord:g} bits/coord)")
    print("round,loss,ghat_norm,live,Mbits_cum,sigma,sec")

    bits = 0.0
    for t in range(start_round, args.rounds):
        tokens = stream.round_batch(t, layout, args.seq_len)
        batch = {"tokens": tokens}
        for name, spec in per_step.items():
            if name == "tokens":
                continue
            key = jax.random.fold_in(jax.random.PRNGKey(7), t)
            batch[name] = jax.random.normal(key, layout + spec.shape[1:],
                                            jnp.float32)
        if "embeds" in per_step or "img_embeds" in per_step:
            s_txt = per_step["tokens"].shape[-1]
            batch["tokens"] = tokens[..., :s_txt]
        mask = jnp.asarray(sampler.mask((args.groups, args.clients)))
        t0 = time.time()
        if checked is not None:
            err, (state, m) = checked(state, batch, mask)
            err.throw()
        else:
            state, m = step(state, batch, mask)
        loss = float(m.loss)
        bits += float(m.uplink_bits)
        if plateau is not None:
            state = state._replace(
                sigma=jnp.asarray(plateau.update(loss), jnp.float32))
        print(f"{t},{loss:.4f},{float(m.grad_est_norm):.3f},"
              f"{int(m.participation)},{bits/1e6:.2f},"
              f"{float(state.sigma):.4f},{time.time()-t0:.2f}")
        if mgr and (t + 1) % args.save_every == 0:
            mgr.save(t + 1, state._asdict())
    if mgr:
        mgr.save(args.rounds, state._asdict())
    print(f"# done: {args.rounds} rounds, {bits/1e6:.1f} Mbit uplink "
          f"({32.0/comp.wire_bits_per_coord:.0f}x less than fp32)")


if __name__ == "__main__":
    main()
