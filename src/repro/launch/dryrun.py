import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on the
production mesh with 512 placeholder host devices, and extract the roofline
terms from the compiled artifact.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2_0_5b \
        --shape train_4k [--multi-pod] [--out results.json]

For each cell this prints/records:
  * compiled memory_analysis (bytes per device — proves it fits),
  * cost_analysis FLOPs / bytes accessed,
  * collective bytes summed from the optimized HLO (all-gather, all-reduce,
    reduce-scatter, all-to-all, collective-permute),
  * the three roofline terms vs TPU v5e (197 TFLOP/s bf16, 819 GB/s HBM,
    ~50 GB/s/link ICI).
"""

import argparse
import dataclasses
import json
import re
import time
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.common import SHAPES, get_arch, list_archs
from repro.core import compression, fedavg
from repro.launch import sharding as SH
from repro.launch.hints import sharding_hints
from repro.launch.mesh import make_production_mesh
from repro.models.api import build_model

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s/link (per-chip aggregate approximation)

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def _dryrun_model(arch, shape):
    """Dry-run model cfg hook. Attention is flash-style KV-chunked
    (layers._flash_kv_attention), which is sharding-transparent — no
    override needed; kept as the per-cell tuning point for §Perf."""
    del shape
    return arch.model


def build_train_cell(arch, shape, mesh, agg_backend="auto",
                     encode_backend="auto", cohort="auto",
                     adversary="none", pipeline=None):
    """Returns (jitted_fn, example_args as ShapeDtypeStructs).

    ``pipeline`` overrides the arch's default zsign codec with a full
    pipeline spec string (e.g. ``cv|zsign_packed``) — proves stateful
    pipelines lower/compile on the production mesh with their client-scope
    slots cohort-sharded and server-scope slots replicated."""
    arch = __import__("dataclasses").replace(arch, model=_dryrun_model(arch, shape))
    bundle = build_model(arch.model)
    plan = SH.make_plan(arch, shape, mesh)
    comp = compression.Pipeline(
        pipeline if pipeline else
        f"zsign(z={arch.zsign_z},sigma={arch.zsign_sigma})")
    fcfg = fedavg.FedConfig(n_clients=plan.n_clients,
                            client_groups=plan.client_groups,
                            local_steps=plan.local_steps,
                            client_lr=arch.client_lr,
                            server_lr=arch.server_lr)
    params_shapes = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
    pspecs = SH.param_specs(params_shapes, mesh, plan,
                            moe_experts=arch.model.moe_experts)
    psh = SH.to_shardings(pspecs, mesh)

    def param_constraint(tree):
        return jax.tree.map(jax.lax.with_sharding_constraint, tree, psh)

    rep = SH.replicated(mesh)

    ctx = SH.round_context(plan, agg_backend=agg_backend,
                           encode_backend=encode_backend, cohort=cohort,
                           adversary=adversary)
    step = fedavg.build_round_step(
        bundle.loss_fn, comp, fcfg, ctx,
        spmd_axes=(plan.client_axes if plan.client_axes else None),
        param_constraint=param_constraint,
        wire_constraint=lambda f: jax.lax.with_sharding_constraint(f, rep))

    state_shapes = jax.eval_shape(
        lambda p: fedavg.init_server_state(p, fcfg, comp,
                                           jax.random.PRNGKey(0)),
        params_shapes)
    comp_state_sh = (None if state_shapes.comp_state is None else
                     SH.to_shardings(SH.wire_state_specs(
                         state_shapes.comp_state, plan), mesh))
    comp_server_sh = (None if state_shapes.comp_server is None else
                      SH.to_shardings(SH.server_state_specs(
                          state_shapes.comp_server, plan), mesh))
    state_sh = fedavg.ServerState(
        params=psh, opt_state=(), comp_state=comp_state_sh, rng=rep,
        round=rep, sigma=rep, comp_server=comp_server_sh)

    per_step = bundle.train_batch_spec(plan.micro, shape.seq_len)
    batch_shapes = fedavg.make_batch_spec(fcfg, per_step)
    bspecs = SH.batch_specs(batch_shapes, plan)
    bsh = SH.to_shardings(bspecs, mesh)
    mask_shape = jax.ShapeDtypeStruct(
        (plan.client_groups, plan.n_clients), jnp.float32)
    mask_sh = NamedSharding(mesh, P(None, SH._axes_entry(plan.client_axes)))

    # donate the server state: in-place params/opt/residual update shows up
    # in the compiled memory analysis as aliased buffers, not copies
    fn = jax.jit(step, in_shardings=(state_sh, bsh, mask_sh),
                 out_shardings=(state_sh, rep), donate_argnums=0)
    return fn, (state_shapes, batch_shapes, mask_shape), plan


def build_prefill_cell(arch, shape, mesh):
    """Prefill: forward to final hidden + last-token logits (serving)."""
    arch = __import__("dataclasses").replace(arch, model=_dryrun_model(arch, shape))
    bundle = build_model(arch.model)
    plan = SH.make_plan(arch, shape, mesh)
    cfg = arch.model
    batch = shape.global_batch
    all_batch_axes = tuple(list(plan.client_axes) + list(plan.micro_axes))

    if cfg.family in ("dense", "moe", "vlm"):
        from repro.models import transformer as T
        def prefill(params, tokens):
            x, _ = T.forward_hidden(params, tokens, cfg)
            return (x[:, -1:] @ T.lm_head(params, cfg)).astype(jnp.float32)
        arg_shapes = (jax.ShapeDtypeStruct((batch, shape.seq_len), jnp.int32),)
        aspec = (P(SH._axes_entry(all_batch_axes), SH._axes_entry(plan.seq_axes)),)
    elif cfg.family == "hybrid":
        from repro.models import hybrid as Hy
        def prefill(params, tokens):
            x, _ = Hy.forward_hidden(params, tokens, cfg)
            return (x[:, -1:] @ Hy._head(params, cfg)).astype(jnp.float32)
        arg_shapes = (jax.ShapeDtypeStruct((batch, shape.seq_len), jnp.int32),)
        aspec = (P(SH._axes_entry(all_batch_axes), SH._axes_entry(plan.seq_axes)),)
    elif cfg.family == "xlstm":
        from repro.models import xlstm as X
        def prefill(params, tokens):
            x = X.forward_hidden(params, tokens, cfg)
            return (x[:, -1:] @ X._head(params, cfg)).astype(jnp.float32)
        arg_shapes = (jax.ShapeDtypeStruct((batch, shape.seq_len), jnp.int32),)
        aspec = (P(SH._axes_entry(all_batch_axes), SH._axes_entry(plan.seq_axes)),)
    else:  # encdec
        from repro.models import encdec as E
        s_src = shape.seq_len // 2
        def prefill(params, embeds):
            mem = E.encode(params, embeds, cfg)
            return mem[:, -1:]
        arg_shapes = (jax.ShapeDtypeStruct((batch, s_src, cfg.d_model),
                                           jnp.float32),)
        aspec = (P(SH._axes_entry(all_batch_axes), SH._axes_entry(plan.seq_axes),
                   None),)

    params_shapes = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
    pspecs = SH.param_specs(params_shapes, mesh, plan,
                            moe_experts=cfg.moe_experts)
    psh = SH.to_shardings(pspecs, mesh)
    ash = tuple(NamedSharding(mesh, s) for s in aspec)
    fn = jax.jit(prefill, in_shardings=(psh,) + ash)
    return fn, (params_shapes,) + arg_shapes, plan


def build_decode_cell(arch, shape, mesh):
    """One-token decode with a KV/state cache of shape.seq_len."""
    bundle = build_model(arch.model)
    plan = SH.make_plan(arch, shape, mesh)
    cfg = arch.model
    batch = shape.global_batch

    params_shapes = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
    pspecs = SH.param_specs(params_shapes, mesh, plan,
                            moe_experts=cfg.moe_experts)
    psh = SH.to_shardings(pspecs, mesh)
    rep = SH.replicated(mesh)

    cache_shapes = jax.eval_shape(lambda: bundle.init_cache(batch, shape.seq_len))
    cspecs = SH.cache_specs(cache_shapes, plan, batch=batch,
                            seq_lens=(shape.seq_len, 2048))
    csh = SH.to_shardings(cspecs, mesh)

    all_batch_axes = tuple(list(plan.client_axes) + list(plan.micro_axes))
    tok_spec = P(SH._axes_entry(all_batch_axes) if batch > 1 else None, None)
    tok_sh = NamedSharding(mesh, tok_spec)

    def serve_step(params, cache, tokens, position):
        return bundle.decode_step(params, cache, tokens, position)

    tok_shape = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    pos_shape = jax.ShapeDtypeStruct((), jnp.int32)
    fn = jax.jit(serve_step, in_shardings=(psh, csh, tok_sh, rep),
                 out_shardings=(rep, csh))
    return fn, (params_shapes, cache_shapes, tok_shape, pos_shape), plan


# ---------------------------------------------------------------------------
# analysis
# ---------------------------------------------------------------------------

_DT_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
             "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
             "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}

_SHAPE_RE = re.compile(r"=\s*\(?([a-z0-9]+)\[([\d,]*)\]")
_COLL_RE = re.compile(r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
                      r"collective-permute)(-start|-done)?\(")


def _line_collective(stripped: str):
    m = _COLL_RE.search(stripped)
    if not m or m.group(2) == "-done":
        return None
    sm = _SHAPE_RE.search(stripped)
    if not sm:
        return None
    n = 1
    for d in sm.group(2).split(","):
        if d:
            n *= int(d)
    return m.group(1), n * _DT_BYTES.get(sm.group(1), 4)


def _parse_computations(hlo_text: str) -> dict:
    """name -> body text. Computations end with a column-0 '}' line."""
    comps = {}
    cur, buf = None, []
    for line in hlo_text.splitlines():
        if cur is None:
            m = re.match(r"(?:ENTRY )?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{", line)
            if m:
                cur = m.group(1)
                buf = []
                if line.startswith("ENTRY"):
                    comps["__entry__"] = cur
        elif line.startswith("}"):
            comps[cur] = buf
            cur = None
        else:
            buf.append(line.strip())
    return comps


def _trip_count(cond_body) -> int:
    """Largest s32 constant in the while condition ~= trip count (scan loops
    are canonical 0..N step 1)."""
    best = 1
    for line in cond_body:
        for m in re.finditer(r"s32\[\] constant\((\d+)\)", line):
            best = max(best, int(m.group(1)))
    return best


def collective_bytes(hlo_text: str) -> dict:
    """Loop-aware collective-byte accounting.

    XLA's cost_analysis counts while-loop bodies ONCE (verified in
    tests/test_roofline.py), so a naive sum over the HLO undercounts scanned
    layers. Here we walk the computation call graph from ENTRY, multiplying
    each while body by its trip count (recovered from the loop condition).
    """
    comps = _parse_computations(hlo_text)
    entry = comps.get("__entry__")
    out = {k: 0 for k in _COLLECTIVES}
    seen_stack = set()

    def walk(name: str, mult: float):
        if name not in comps or name in seen_stack:
            return
        seen_stack.add(name)
        for line in comps[name]:
            lc = _line_collective(line)
            if lc:
                out[lc[0]] += int(lc[1] * mult)
            wm = re.search(r"while\(.*?\), condition=%?([\w.\-]+), "
                           r"body=%?([\w.\-]+)", line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                trips = _trip_count(comps.get(cond, []))
                walk(body, mult * trips)
                continue
            for cm in re.finditer(r"(?:calls|to_apply|body|condition|"
                                  r"branch_computations)=\{?%?([\w.\-]+)", line):
                walk(cm.group(1), mult)
        seen_stack.discard(name)

    if entry:
        walk(entry, 1.0)
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def collective_bytes_naive(hlo_text: str) -> dict:
    """Flat sum (what cost_analysis effectively sees) — kept for the
    methodology comparison in EXPERIMENTS.md."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        lc = _line_collective(line.strip())
        if lc:
            out[lc[0]] += lc[1]
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def analyze(fn, arg_shapes, mesh, label: str) -> dict:
    t0 = time.time()
    lowered = fn.lower(*arg_shapes)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    coll_naive = collective_bytes_naive(hlo)

    res = {
        "label": label,
        "devices": mesh.size,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        # raw compiled-artifact numbers (per partitioned module; while-loop
        # bodies counted once — see roofline.py docstring)
        "hlo_flops_raw": float(cost.get("flops", 0.0)),
        "hlo_bytes_raw": float(cost.get("bytes accessed", 0.0)),
        # loop-aware collective accounting from the same HLO
        "collective_bytes_per_device": coll["total"],
        "collective_bytes_naive": coll_naive["total"],
        "collectives": {k: v for k, v in coll.items() if k != "total" and v},
    }
    for attr in ("output_size_in_bytes", "temp_size_in_bytes",
                 "argument_size_in_bytes", "generated_code_size_in_bytes"):
        res[attr] = getattr(mem, attr, None)
    return res


def run_cell(arch_id: str, shape_name: str, *, multi_pod: bool,
             agg_backend: str = "auto", encode_backend: str = "auto",
             cohort: str = "auto", adversary: str = "none",
             pipeline: str = None) -> dict:
    arch = get_arch(arch_id)
    shape = SHAPES[shape_name]
    bundle = build_model(arch.model)
    if shape_name == "long_500k" and not bundle.subquadratic:
        return {"label": f"{arch_id}/{shape_name}", "skipped":
                "full-attention arch: no sub-quadratic path (DESIGN.md)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    plan0 = SH.make_plan(arch, shape, mesh)
    with mesh, sharding_hints(mesh, plan0.seq_axes, plan0.micro_axes):
        if shape.kind == "train":
            fn, args, plan = build_train_cell(arch, shape, mesh, agg_backend,
                                              encode_backend, cohort,
                                              adversary, pipeline)
        elif shape.kind == "prefill":
            fn, args, plan = build_prefill_cell(arch, shape, mesh)
        else:
            fn, args, plan = build_decode_cell(arch, shape, mesh)
        label = f"{arch_id}/{shape_name}/{'pod2x16x16' if multi_pod else '16x16'}"
        res = analyze(fn, args, mesh, label)
        res["plan"] = dataclasses.asdict(plan)

    from repro.launch import roofline as RF
    terms = RF.terms_for(arch, shape, plan,
                         res["collective_bytes_per_device"], multi_pod)
    secs = terms.seconds()
    res.update({
        "flops_per_device": terms.flops_per_dev,
        "hbm_bytes_per_device": terms.hbm_bytes_per_dev,
        "model_flops_total": terms.model_flops_total,
        "t_compute_s": secs["compute"],
        "t_memory_s": secs["memory"],
        "t_collective_s": secs["collective"],
        "dominant": terms.dominant(),
        "roofline_fraction": round(terms.roofline_fraction(), 4),
        "useful_ratio": round(terms.model_flops_total /
                              (terms.flops_per_dev * terms.devices + 1e-9), 4),
    })
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--agg-backend", default="auto",
                    choices=list(compression.AGG_BACKENDS))
    ap.add_argument("--encode-backend", default="auto",
                    choices=list(compression.ENCODE_BACKENDS))
    ap.add_argument("--cohort", default="auto",
                    help="cohort execution policy: auto | vmap | "
                         "stream(shard=K|auto[,unroll=U][,devices=D|auto]"
                         "[,feed=device|host])")
    ap.add_argument("--adversary", default="none", metavar="SPEC",
                    help="wire-level fault-injection policy compiled into "
                         "the train cell (none | sign_flip(f=..) | "
                         "byte_corrupt(f=..,p=..) | collude(f=..) | "
                         "dropout(f=..)) — proves attacks lower/compile on "
                         "the production mesh")
    ap.add_argument("--pipeline", default=None, metavar="SPEC",
                    help="full compression pipeline spec overriding the "
                         "arch default, e.g. 'cv|zsign_packed' or "
                         "'ef|topk(frac=0.01)' (grammar: docs/API.md) — "
                         "compiles stateful pipelines on the production "
                         "mesh")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    for arch_id in archs:
        for shape_name in shapes:
            for mp in meshes:
                try:
                    res = run_cell(arch_id, shape_name, multi_pod=mp,
                                   agg_backend=args.agg_backend,
                                   encode_backend=args.encode_backend,
                                   cohort=args.cohort,
                                   adversary=args.adversary,
                                   pipeline=args.pipeline)
                except Exception as e:  # record the failure, keep sweeping
                    res = {"label": f"{arch_id}/{shape_name}/"
                           f"{'multi' if mp else 'single'}",
                           "error": f"{type(e).__name__}: {e}"}
                results.append(res)
                print(json.dumps(res, default=str))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1, default=str)


if __name__ == "__main__":
    main()
