"""Production mesh construction.

Defined as a FUNCTION so importing this module never touches jax device
state.  Single pod: 16 x 16 = 256 chips, axes (data, model).  Multi-pod:
2 x 16 x 16 = 512 chips, axes (pod, data, model) — the ``pod`` axis is the
cross-DCN dimension where z-SignFedAvg's 1-bit aggregation pays most.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_cohort_mesh(devices: int = 0):
    """1-D ``clients`` mesh for the streaming cohort engine's device axis
    (core/fedavg.py ``stream(devices=D)``): the shard sequence partitions
    over this axis and the per-device wire accumulators meet in one O(d)
    psum. ``devices=0`` takes every local device. On a CPU-only host,
    simulate a multi-device mesh with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=D``."""
    n = devices or jax.device_count()
    if n > jax.device_count():
        raise ValueError(f"cohort mesh wants {n} devices but only "
                         f"{jax.device_count()} are visible")
    return jax.make_mesh((n,), ("clients",))


def axis_size(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n
