"""Render EXPERIMENTS.md roofline tables from dry-run sweep JSONs.

    PYTHONPATH=src python -m repro.launch.report dryrun_single.json [multi.json]
"""
from __future__ import annotations

import json
import sys


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def fmt_s(x):
    if x is None:
        return "-"
    if x < 1e-3:
        return f"{x*1e6:.0f}us"
    if x < 1.0:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def table(results):
    hdr = ("| cell | plan | HBM/dev (args+temp) | t_compute | t_memory | "
           "t_collective | dominant | roofline frac | MODEL/HLO flops |")
    sep = "|" + "---|" * 9
    rows = [hdr, sep]
    for r in sorted(results, key=lambda x: x.get("label", "")):
        label = r.get("label", "?")
        if "skipped" in r:
            rows.append(f"| {label} | — | — | — | — | — | skipped | — | — |")
            continue
        if "error" in r:
            rows.append(f"| {label} | — | ERROR: {r['error'][:60]} "
                        f"| — | — | — | — | — | — |")
            continue
        p = r.get("plan", {})
        plan = (f"cl={'x'.join(p.get('client_axes') or ['seq'])}"
                f"({p.get('n_clients')}x{p.get('client_groups')}g)")
        mem = (r.get("argument_size_in_bytes") or 0) + \
              (r.get("temp_size_in_bytes") or 0)
        rows.append(
            f"| {label} | {plan} | {fmt_bytes(mem)} "
            f"| {fmt_s(r.get('t_compute_s'))} | {fmt_s(r.get('t_memory_s'))} "
            f"| {fmt_s(r.get('t_collective_s'))} | {r.get('dominant')} "
            f"| {r.get('roofline_fraction')} | {r.get('useful_ratio')} |")
    return "\n".join(rows)


def main():
    for path in sys.argv[1:]:
        results = json.load(open(path))
        print(f"\n### {path} ({len(results)} cells)\n")
        print(table(results))


if __name__ == "__main__":
    main()
