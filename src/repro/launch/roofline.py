"""Analytic roofline terms per (arch x shape x plan).

Why analytic on top of the compiled artifact: XLA's ``cost_analysis`` counts
while-loop bodies exactly once (verified in tests/test_roofline.py), and this
framework deliberately scans over depth — so raw HLO FLOPs/bytes undercount
by ~L x. Collective bytes are recovered loop-aware from the HLO itself
(launch/dryrun.collective_bytes); FLOPs and HBM bytes are computed here from
exact parameter counts (jax.eval_shape of the real init — not hand-listed)
plus the standard transformer accounting, and cross-checked against the raw
HLO numbers in EXPERIMENTS.md.

Conventions:
  fwd matmul FLOPs      = 2 * N_active_matmul * tokens
  bwd                   = 2x fwd;  full remat adds ~1x fwd  -> 8 N D total
  attention (causal)    = 2 * S^2 * H * hd * B per layer fwd (qk + av, halved)
  MODEL_FLOPS (useful)  = 6 * N_active * D   (the spec's headline number)
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
DEVICES = {False: 256, True: 512}


def param_counts(arch) -> Dict[str, int]:
    """Exact counts from the real init's shape tree."""
    from repro.models.api import build_model
    bundle = build_model(arch.model)
    shapes = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    total = expert = embed = 0
    for path, leaf in flat:
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        if "moe" in keys and keys[-1] in ("w1", "w2", "w3"):
            expert += n
        if keys[-1] in ("embed", "lm_head"):
            embed += n
    m = arch.model
    active = total - (expert - expert * m.moe_topk / max(1, m.moe_experts))
    return {"total": int(total), "expert": int(expert), "embed": int(embed),
            "active": int(active),
            "matmul_active": int(active - embed +
                                 (m.d_model * m.vocab))}  # lm head matmul


def _attn_flops_fwd(m, tokens_per_seq: int, n_seqs: int, causal=True) -> float:
    if m.family == "xlstm":
        # mLSTM quadratic form on 3/4 of layers + sLSTM linear
        n_q = m.n_layers * 3 // 4
        f = 4 * tokens_per_seq ** 2 * m.d_model * n_seqs * n_q * 0.5
        return f
    n_attn = m.n_layers
    if m.family == "hybrid":
        n_attn = m.n_layers // 8
    if m.family == "encdec":
        # enc self (bidir) + dec self (causal) on seq/2 each + cross
        s = tokens_per_seq // 2
        per = (4 * s * s * m.n_heads * (m.d_model // m.n_heads))
        return n_seqs * m.n_layers * (per + per * 0.5 + per)
    S = tokens_per_seq
    eff = S if m.sliding_window == 0 else min(S, 2 * m.sliding_window)
    per = 4 * S * eff * m.n_heads * (m.d_model // m.n_heads)
    return n_seqs * n_attn * per * (0.5 if causal and m.sliding_window == 0 else 1.0)


@dataclasses.dataclass
class Terms:
    flops_per_dev: float
    hbm_bytes_per_dev: float
    coll_bytes_per_dev: float
    model_flops_total: float
    devices: int

    def seconds(self):
        return {"compute": self.flops_per_dev / PEAK_FLOPS,
                "memory": self.hbm_bytes_per_dev / HBM_BW,
                "collective": self.coll_bytes_per_dev / ICI_BW}

    def dominant(self):
        s = self.seconds()
        return max(s, key=s.get)

    def roofline_fraction(self):
        """useful-compute time / max(term) — the score we hillclimb."""
        s = self.seconds()
        t_useful = (self.model_flops_total / self.devices) / PEAK_FLOPS
        return t_useful / max(s.values())


def train_terms(arch, shape, plan, coll_bytes_per_dev: float,
                multi_pod: bool) -> Terms:
    m = arch.model
    pc = param_counts(arch)
    n_dev = DEVICES[multi_pod]
    tokens = shape.global_batch * shape.seq_len * plan.local_steps
    n_seqs = shape.global_batch * plan.local_steps

    mm = 2.0 * pc["matmul_active"] * tokens          # fwd matmul
    at = _attn_flops_fwd(m, shape.seq_len, n_seqs)
    fwd = mm + at
    total_flops = 4.0 * fwd                          # fwd + bwd(2x) + remat(1x)
    model_flops = 6.0 * pc["active"] * tokens

    # HBM traffic model, per device:
    #  weights: replica shard read 3x (fwd, remat, bwd) + grad write + server
    #  update rw; activations: ~12 d_model-sized rw per token per layer-pass.
    bytesize = 2 if m.dtype == jnp.bfloat16 else 4
    repl_ways = 16 if not arch.big else 256
    if multi_pod and arch.big:
        repl_ways = 256
    w_dev = pc["total"] * bytesize / repl_ways
    tok_dev = tokens / n_dev
    # ~12 d_model-sized reads/writes per token per layer, x3 passes (fwd,
    # remat, bwd)
    act = tok_dev * m.d_model * bytesize * 12 * m.n_layers * 3
    # each client pass streams its replica shard 3x; sequential groups repeat;
    # +4 for grad write + server param read/modify/write
    w_traffic = w_dev * (3 * plan.client_groups + 4)
    hbm = w_traffic + act
    return Terms(total_flops / n_dev, hbm, coll_bytes_per_dev,
                 model_flops, n_dev)


def prefill_terms(arch, shape, plan, coll_bytes_per_dev: float,
                  multi_pod: bool) -> Terms:
    m = arch.model
    pc = param_counts(arch)
    n_dev = DEVICES[multi_pod]
    tokens = shape.global_batch * shape.seq_len
    mm = 2.0 * (pc["matmul_active"] - m.d_model * m.vocab) * tokens \
        + 2.0 * m.d_model * m.vocab * shape.global_batch  # last-token head
    at = _attn_flops_fwd(m, shape.seq_len, shape.global_batch)
    total = mm + at
    model_flops = total
    bytesize = 2 if m.dtype == jnp.bfloat16 else 4
    w_dev = pc["total"] * bytesize / n_dev
    act = tokens / n_dev * m.d_model * bytesize * 12
    return Terms(total / n_dev, w_dev + act, coll_bytes_per_dev,
                 model_flops, n_dev)


def decode_terms(arch, shape, plan, coll_bytes_per_dev: float,
                 multi_pod: bool) -> Terms:
    m = arch.model
    pc = param_counts(arch)
    n_dev = DEVICES[multi_pod]
    B = shape.global_batch
    mm = 2.0 * pc["matmul_active"] * B
    # attention reads the KV cache: flops 4*S_eff*H*hd per token
    S_eff = shape.seq_len if m.sliding_window == 0 else min(
        shape.seq_len, m.sliding_window)
    n_attn = {"hybrid": m.n_layers // 8}.get(m.family, m.n_layers)
    if m.family == "xlstm":
        at, kv_bytes = 0.0, m.n_layers * B * m.d_model ** 2 / m.n_heads * 4
    else:
        at = 4.0 * S_eff * m.n_kv_heads * (m.d_model // m.n_heads) * B * n_attn
        kv_bytes = (2 * S_eff * m.n_kv_heads * (m.d_model // m.n_heads)
                    * B * n_attn * 2)
    total = mm + at
    bytesize = 2 if m.dtype == jnp.bfloat16 else 4
    w_dev = pc["total"] * bytesize / n_dev if arch.big else \
        pc["total"] * bytesize / 16
    hbm = w_dev + kv_bytes / n_dev
    return Terms(total / n_dev, hbm, coll_bytes_per_dev, total, n_dev)


def terms_for(arch, shape, plan, coll_bytes_per_dev, multi_pod) -> Terms:
    if shape.kind == "train":
        return train_terms(arch, shape, plan, coll_bytes_per_dev, multi_pod)
    if shape.kind == "prefill":
        return prefill_terms(arch, shape, plan, coll_bytes_per_dev, multi_pod)
    return decode_terms(arch, shape, plan, coll_bytes_per_dev, multi_pod)
