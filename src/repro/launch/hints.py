"""Sharding hints: a tiny context the launcher sets so model code can pin
activations to the sequence-parallel layout without threading mesh/plan
through every layer.

Baseline finding that motivates this (EXPERIMENTS.md §Perf, iteration 1):
with weights TP-sharded and activations seq-sharded but *unconstrained*
inside the layer scan, GSPMD chose to all-gather the 117 MB activations every
layer and emit fp32 partial-sum all-reduces per attention chunk — 34 GB/dev
of collectives for a 0.5B model. Pinning activations (B, S, D) with S on the
`model` axis flips GSPMD to FSDP semantics: it gathers the (much smaller)
layer weights instead.

Inside the federated engine the client dimension is vmapped; the engine uses
``jax.vmap(..., spmd_axis_name=client_axes)`` so these per-client constraints
compose with the client sharding.
"""
from __future__ import annotations

from contextlib import contextmanager

import jax
import jax.ad_checkpoint
from jax.sharding import NamedSharding, PartitionSpec as P

_CTX = {"mesh": None, "seq_axes": None, "batch_axes": None}


def _register_barrier_rules():
    """Backfill JVP/transpose/vmap rules for ``optimization_barrier``.

    The jax pinned in this image (0.4.x) exposes the primitive but ships no
    differentiation or batching rules, so any grad/vmap through a barrier
    raises NotImplementedError. The barrier is semantically the identity;
    these rules (barrier the tangents/cotangents, pass batch dims through)
    match what later jax versions ship natively. No-ops when the rules
    already exist or the private layout shifts.
    """
    try:
        from jax._src.lax import lax as _lax_internal
        from jax.interpreters import ad, batching
        prim = _lax_internal.optimization_barrier_p

        if prim not in ad.primitive_jvps:
            def _jvp(primals, tangents):
                tangents = [ad.instantiate_zeros(t) if type(t) is ad.Zero
                            else t for t in tangents]
                return prim.bind(*primals), prim.bind(*tangents)
            ad.primitive_jvps[prim] = _jvp

        if prim not in ad.primitive_transposes:
            def _transpose(cts, *primals):
                return tuple(prim.bind(*[ad.instantiate_zeros(ct)
                                         for ct in cts]))
            ad.primitive_transposes[prim] = _transpose

        if prim not in batching.primitive_batchers:
            def _batch(args, dims, **params):
                return prim.bind(*args, **params), dims
            batching.primitive_batchers[prim] = _batch
    except Exception:  # pragma: no cover - newer jax ships these natively
        pass


_register_barrier_rules()


def opt_barrier(x):
    """``jax.lax.optimization_barrier`` usable under grad and vmap (the
    pinned jax lacks the rules; see _register_barrier_rules)."""
    return jax.lax.optimization_barrier(x)


@contextmanager
def sharding_hints(mesh, seq_axes, batch_axes=None):
    old = dict(_CTX)
    _CTX["mesh"] = mesh
    _CTX["seq_axes"] = tuple(seq_axes) if seq_axes else None
    _CTX["batch_axes"] = tuple(batch_axes) if batch_axes else None
    try:
        yield
    finally:
        _CTX.update(old)


def _batch_entry(x, dim0_size=None):
    """Spec entry for the leading batch dim (None if not shardable)."""
    mesh, batch_axes = _CTX["mesh"], _CTX["batch_axes"]
    if mesh is None or not batch_axes:
        return None
    n = 1
    for a in batch_axes:
        n *= mesh.shape[a]
    if dim0_size is None or dim0_size % n != 0:
        return None
    return _entry(batch_axes)


def _entry(axes):
    return axes[0] if len(axes) == 1 else tuple(axes)


def seq_shard(x, seq_dim: int = 1):
    """Pin activation x (B, S, ...) to (batch-, sequence-)parallel layout.
    The batch entry matters for the big-arch plans (micro over `data`):
    an all-None batch spec would force replication of the micro dim
    (measured: 128x inflation of every activation on qwen2.5-32b)."""
    mesh, seq_axes = _CTX["mesh"], _CTX["seq_axes"]
    if mesh is None or seq_axes is None:
        return x
    if x.shape[seq_dim] % 16 != 0:
        return x
    spec = [None] * x.ndim
    spec[seq_dim] = _entry(seq_axes)
    if seq_dim != 0:
        spec[0] = _batch_entry(x, x.shape[0])
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


def fsdp_params(lp, *, skip=("w1", "w2", "w3")):
    """FSDP just-in-time weight gather for one layer's params.

    Pins every >=2D leaf (except MoE expert tensors, which stay
    expert-parallel) to a REPLICATED layout inside the layer body: GSPMD
    all-gathers the (small) weight shard instead of the (large) sequence-
    sharded activations, and the transpose in backward becomes the FSDP
    reduce-scatter of weight grads. ``skip`` names expert tensors to keep
    sharded; pass skip=() for dense layers whose w1/w2/w3 are plain MLP mats.
    """
    mesh = _CTX["mesh"]
    if mesh is None:
        return lp
    rep = NamedSharding(mesh, P())

    def maybe(path, x):
        name = str(getattr(path[-1], "key", getattr(path[-1], "idx", "")))
        if x.ndim >= 2 and name not in skip:
            # barrier pins the all-gather to the stored (bf16) dtype — XLA
            # otherwise hoists fp32 converts before the gather (2x bytes).
            # checkpoint_name lets the layer remat policy SAVE the gathered
            # copy (one gather instead of two per layer per round).
            return jax.ad_checkpoint.checkpoint_name(
                opt_barrier(
                    jax.lax.with_sharding_constraint(x, rep)),
                "fsdp_gathered")
        return x

    return jax.tree_util.tree_map_with_path(maybe, lp)


def gather_seq(x):
    """Replicate a (small) tensor across the sequence axis while KEEPING the
    batch dim sharded — used for GQA K/V inside attention so GSPMD gathers
    these 16 MB bf16 tensors instead of the 235 MB fp32 queries (measured;
    EXPERIMENTS.md §Perf iteration 3)."""
    mesh = _CTX["mesh"]
    if mesh is None:
        return x
    spec = [None] * x.ndim
    spec[0] = _batch_entry(x, x.shape[0])
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


def seq_shard_count() -> int:
    """Number of sequence shards under the current hints (1 off-mesh)."""
    mesh, seq_axes = _CTX["mesh"], _CTX["seq_axes"]
    if mesh is None or seq_axes is None:
        return 1
    n = 1
    for a in seq_axes:
        n *= mesh.shape[a]
    return n


def shard_dim(x, dim: int, axes=None):
    """Pin dim of x to the given (default: seq) axes; batch dim0 kept."""
    mesh = _CTX["mesh"]
    axes = axes if axes is not None else _CTX["seq_axes"]
    if mesh is None or axes is None:
        return x
    spec = [None] * x.ndim
    spec[dim] = _entry(tuple(axes))
    if dim != 0:
        spec[0] = _batch_entry(x, x.shape[0])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))
