"""Sharding rules: map every parameter / batch / KV-cache leaf to a
PartitionSpec, per (architecture x shape x mesh) parallel plan.

Plans (DESIGN.md §4):
  single-pod, regular arch : clients on `data` (16 parallel), replica TP/FSDP
                             over `model`, sequence-parallel activations.
  single-pod, big arch     : sequential client groups (scan), replica FSDP
                             over (`data`,`model`) = 256-way.
  multi-pod, regular arch  : clients on (`pod`,`data`) = 32 parallel.
  multi-pod, big arch      : one client per pod (the cross-DCN z-sign
                             aggregation), replica over (`data`,`model`).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.context import RoundContext
from repro.launch.mesh import axis_size


@dataclasses.dataclass(frozen=True)
class ParallelPlan:
    client_axes: Tuple[str, ...]
    micro_axes: Tuple[str, ...]   # within-client batch axes
    seq_axes: Tuple[str, ...]
    replica_axes: Tuple[str, ...]
    n_clients: int
    client_groups: int
    micro: int                    # per-client per-local-step batch
    local_steps: int


def make_plan(arch, shape, mesh) -> ParallelPlan:
    multi = "pod" in mesh.axis_names
    E = arch.local_steps if shape.kind == "train" else 1
    if arch.big:
        client_axes = ("pod",) if multi else ()
        micro_axes, seq_axes = ("data",), ("model",)
        replica_axes = ("data", "model")
        n_clients = axis_size(mesh, client_axes) if client_axes else 1
        groups = 1 if multi else arch.seq_client_groups
    else:
        client_axes = ("pod", "data") if multi else ("data",)
        micro_axes, seq_axes = (), ("model",)
        replica_axes = ("model",)
        n_clients = axis_size(mesh, client_axes)
        groups = 1
    denom = max(1, groups * n_clients * E)
    micro = max(1, shape.global_batch // denom)
    return ParallelPlan(client_axes, micro_axes, seq_axes, replica_axes,
                        n_clients, groups, micro, E)


def cohort_plan(n_clients: int, *, client_groups: int = 1, micro: int = 1,
                local_steps: int = 1) -> ParallelPlan:
    """ParallelPlan for the 1-D cohort mesh (launch.mesh.make_cohort_mesh):
    clients shard over the ``clients`` axis; params, activations and the
    aggregated wire buffer stay replicated (no model/tensor parallelism).
    ``wire_state_specs`` under this plan lays the per-client state slots
    (EF residuals, cv client variates — every client-scope StateSlot)
    out SHARDED along the cohort axis — the layout the streaming engine's
    ``stream(devices=D)`` shard_map produces, so state rows persist
    device-local across rounds and never reshard. Server-scope slots stay
    replicated (``server_state_specs``)."""
    return ParallelPlan(client_axes=("clients",), micro_axes=(),
                        seq_axes=(), replica_axes=(),
                        n_clients=n_clients, client_groups=client_groups,
                        micro=micro, local_steps=local_steps)


def round_context(plan: ParallelPlan, *, agg_backend: str = "auto",
                  encode_backend: str = "auto",
                  dynamic_sigma: bool = False,
                  cohort: str = "auto",
                  adversary: str = "none") -> RoundContext:
    """The launcher-standard RoundContext for a parallel plan.

    One construction point for every mesh launcher (dryrun, and the shape
    the train CLI mirrors): the CLI backend selectors, donation on (the
    launchers always donate the server state into the jitted step), and
    ``weights_are_mask=True`` — the launchers' participation samplers emit
    exact 0/1 membership masks, so the popcount sign-reduce specialization
    is safe for any plan. ``plan`` is accepted (and currently unused beyond
    documentation) so per-plan policy can key off client topology later
    without touching call sites. ``adversary`` threads the wire-level
    fault-injection policy (fed/adversary.py) into the round step; the
    launchers' exact 0/1 masks mean every robust ``agg=`` mode is available
    under it. ``debug_wire`` is left to its REPRO_DEBUG_WIRE env default.
    """
    del plan
    return RoundContext(agg_backend=agg_backend,
                        encode_backend=encode_backend,
                        weights_are_mask=True, dynamic_sigma=dynamic_sigma,
                        donate_state=True, cohort=cohort,
                        adversary=adversary)


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

_COL_KEYS = ("wq", "wk", "wv", "w1", "w3", "wqkv", "wx", "in_proj", "wif",
             "dt_proj")
_ROW_KEYS = ("wo", "w2", "out_proj", "x_proj")


def _divides(n: int, mesh, axes) -> bool:
    return n % axis_size(mesh, axes) == 0


def _param_spec(path_keys, shape, mesh, replica_axes, moe_experts: int):
    name = path_keys[-1]
    ndim = len(shape)
    spec = [None] * ndim

    def set_dim(d, axes):
        spec[d] = axes[0] if len(axes) == 1 else tuple(axes)

    if name == "router" or ndim == 1:
        return P()
    if name == "embed":
        for axes in (replica_axes, ("model",), ("data",)):
            if set(axes) <= set(replica_axes) and _divides(shape[0], mesh, axes):
                set_dim(0, axes)
                break
        return P(*spec)
    if name == "lm_head":
        for axes in (replica_axes, ("model",), ("data",)):
            if set(axes) <= set(replica_axes) and _divides(shape[-1], mesh, axes):
                set_dim(ndim - 1, axes)
                break
        return P(*spec)
    # MoE expert tensors: (..., E, D, F) — expert dim over `model`,
    # remaining replica axes over the ff dim.
    if moe_experts > 0 and ndim >= 3 and shape[-3] == moe_experts and name in (
            "w1", "w2", "w3"):
        rest = [a for a in replica_axes if a != "model"]
        if _divides(moe_experts, mesh, ("model",)):
            spec[ndim - 3] = "model"
            if rest and _divides(shape[-1], mesh, tuple(rest)):
                # storage stays (E:'model' x F:'data') = 256-way; the ep
                # einsum path JIT-gathers the F shards per layer in bf16
                # (models/layers.py) — storing E-only 16-way costs 16x HBM
                # (measured: jamba temp 172 -> 607 GB).
                set_dim(ndim - 1, rest)
        elif _divides(shape[-1], mesh, replica_axes):
            set_dim(ndim - 1, replica_axes)
        return P(*spec)
    if ndim >= 2 and name in _COL_KEYS and _divides(shape[-1], mesh, replica_axes):
        set_dim(ndim - 1, replica_axes)
        return P(*spec)
    if ndim >= 2 and name in _ROW_KEYS and _divides(shape[-2], mesh, replica_axes):
        set_dim(ndim - 2, replica_axes)
        return P(*spec)
    # fallback: biggest trailing dim that divides
    for d in (ndim - 1, ndim - 2):
        if d >= 0 and shape[d] >= 1024 and _divides(shape[d], mesh, replica_axes):
            set_dim(d, replica_axes)
            return P(*spec)
    return P()


def param_specs(param_shapes, mesh, plan: ParallelPlan, moe_experts: int = 0):
    """param_shapes: pytree of ShapeDtypeStruct (jax.eval_shape of init)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(param_shapes)
    out = []
    for path, leaf in flat:
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        out.append(_param_spec(keys, leaf.shape, mesh, plan.replica_axes,
                               moe_experts))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# batch / state / cache specs
# ---------------------------------------------------------------------------

def _axes_entry(axes):
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else tuple(axes)


def batch_specs(batch_shapes, plan: ParallelPlan):
    """Round-batch leaves have layout (groups, n_clients, E, micro, S, ...)."""
    def spec(leaf):
        ndim = len(leaf.shape)
        s = [None] * ndim
        if ndim >= 2:
            s[1] = _axes_entry(plan.client_axes)
        if ndim >= 4:
            s[3] = _axes_entry(plan.micro_axes)
        if ndim >= 5:
            s[4] = _axes_entry(plan.seq_axes)
        return P(*s)

    return jax.tree.map(spec, batch_shapes)


def wire_state_specs(cstate_shapes, plan: ParallelPlan):
    """Per-client flat compressor residuals (core/wire.py codec): layout
    (client_groups, n_clients, n_coords). Clients shard over the plan's
    client axes; the flat coordinate axis stays replicated — residuals are
    read/written only by their own client, so no cross-client resharding
    occurs. The wire payloads themselves (uint8 bitpacked buffers) are 8-32x
    smaller than fp32 params and feed one collective; they stay replicated
    by construction in core/fedavg.py. That includes the compressed-domain
    group scan's (client_groups, n_clients, n_bytes) payload stack: at
    1 bit/coord the whole stack is G*N/32 the size of ONE dense f32 partial,
    so replicating it costs less than the per-group f32 accumulate it
    replaced.

    Under the 1-D cohort mesh (``cohort_plan`` + ``make_cohort_mesh``) the
    client axis is ``clients``, matching the sharded residual output of the
    streaming engine's ``stream(devices=D)`` shard_map: each device keeps
    exactly its own clients' residual rows round over round.

    The tree is the KEYED multi-slot client state of Pipeline.init_state
    (one ``(G, N, ...)`` leaf per client-scope StateSlot — EF residuals,
    cv client variates, ...); every slot follows the same client-axis
    layout. Server-scope slots (ServerState.comp_server) are NOT in this
    tree — they are shared, see ``server_state_specs``."""
    def spec(leaf):
        s = [None] * len(leaf.shape)
        if len(leaf.shape) >= 2:
            s[1] = _axes_entry(plan.client_axes)
        return P(*s)

    return jax.tree.map(spec, cstate_shapes)


def server_state_specs(server_shapes, plan: ParallelPlan):
    """SHARED server-scope pipeline state (ServerState.comp_server: the cv
    server variate and any future server-scope StateSlot). One flat
    ``(n_coords,)`` row per slot, read by EVERY client's pre-encode and
    written once in the server finish — fully replicated, exactly like the
    params it corrects. The streaming engine broadcasts it into the
    ``stream(devices=D)`` shard_map as a replicated operand, so this spec
    keeps the round free of comp_server collectives."""
    del plan
    return jax.tree.map(lambda leaf: P(), server_shapes)


def cache_specs(cache_shapes, plan: ParallelPlan, *, batch: int,
                seq_lens: Tuple[int, ...]):
    """Decode KV/state cache: seq dims over seq(+micro when batch==1) axes,
    batch dims over client+micro axes, large feature dims over `model`."""
    big_seq_axes = plan.seq_axes if batch > 1 else tuple(
        list(plan.client_axes) + list(plan.micro_axes) + list(plan.seq_axes))
    batch_axes = tuple(list(plan.client_axes) + list(plan.micro_axes))

    def spec(leaf):
        ndim = len(leaf.shape)
        s = [None] * ndim
        got_seq = False
        for d, size in enumerate(leaf.shape):
            if size in seq_lens and not got_seq:
                s[d] = _axes_entry(big_seq_axes)
                got_seq = True
            elif size == batch and batch > 1 and s[d] is None and d < ndim - 1:
                if batch % axis_size_tuple(batch_axes) == 0:
                    s[d] = _axes_entry(batch_axes)
        if not got_seq:
            # recurrent state: shard the largest model-divisible feature dim
            for d in range(ndim - 1, -1, -1):
                if s[d] is None and leaf.shape[d] >= 1024 and \
                        leaf.shape[d] % 16 == 0:
                    s[d] = "model"
                    break
        return P(*s)

    return jax.tree.map(spec, cache_shapes)


_MESH_SIZES = {"pod": 2, "data": 16, "model": 16}


def axis_size_tuple(axes) -> int:
    n = 1
    for a in axes:
        n *= _MESH_SIZES[a]
    return n


def to_shardings(spec_tree, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def replicated(mesh):
    return NamedSharding(mesh, P())
