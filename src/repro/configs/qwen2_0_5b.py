"""qwen2-0.5b [arXiv:2407.10671; hf]
24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936, QKV bias."""
import jax.numpy as jnp
from repro.configs.common import ArchConfig
from repro.models.api import ModelCfg

ARCH = ArchConfig(
    arch_id="qwen2_0_5b",
    source="arXiv:2407.10671",
    model=ModelCfg(name="qwen2-0.5b", family="dense",
                   n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
                   d_ff=4864, vocab=151936, qkv_bias=True,
                   dtype=jnp.bfloat16,
                       remat_save_weights=True),
    notes="GQA kv=2, QKV bias, tied embeddings")
