"""jamba-1.5-large-398b [arXiv:2403.19887; hf]
72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536,
Mamba:attn 7:1 interleave, MoE 16e top-2 on alternate sublayers."""
import jax.numpy as jnp
from repro.configs.common import ArchConfig
from repro.models.api import ModelCfg

ARCH = ArchConfig(
    arch_id="jamba_1_5_large_398b",
    source="arXiv:2403.19887",
    model=ModelCfg(name="jamba-1.5-large-398b", family="hybrid",
                   n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
                   d_ff=24576, vocab=65536, moe_experts=16, moe_topk=2, moe_ep=True,
                   dtype=jnp.bfloat16),
    big=True, seq_client_groups=2,
    notes="398B hybrid; sub-quadratic (mamba) => runs long_500k")
