"""h2o-danube-3-4b [arXiv:2401.16818; unverified]
24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000, sliding window."""
import jax.numpy as jnp
from repro.configs.common import ArchConfig
from repro.models.api import ModelCfg

ARCH = ArchConfig(
    arch_id="h2o_danube_3_4b",
    source="arXiv:2401.16818 (unverified)",
    model=ModelCfg(name="h2o-danube-3-4b", family="dense",
                   n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8,
                   d_ff=10240, vocab=32000, sliding_window=4096,
                   dtype=jnp.bfloat16),
    notes="llama+mistral mix: SWA(4096) => sub-quadratic, runs long_500k")
