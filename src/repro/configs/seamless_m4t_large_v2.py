"""seamless-m4t-large-v2 [arXiv:2308.11596; hf]
enc-dec, 24L per stack, d_model=1024 16H (kv=16) d_ff=8192 vocab=256206;
speech frontend is a STUB (precomputed frame embeddings)."""
import jax.numpy as jnp
from repro.configs.common import ArchConfig
from repro.models.api import ModelCfg

ARCH = ArchConfig(
    arch_id="seamless_m4t_large_v2",
    source="arXiv:2308.11596",
    model=ModelCfg(name="seamless-m4t-large-v2", family="encdec",
                   n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
                   d_ff=8192, vocab=256206, dtype=jnp.bfloat16,
                       remat_save_weights=True),
    notes="24 enc + 24 dec; train seq split src:tgt 50:50")
