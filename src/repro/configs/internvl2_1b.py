"""internvl2-1b [arXiv:2404.16821; hf]
LM backbone = qwen2-0.5b spec (24L d_model=896 14H kv=2 d_ff=4864,
vocab=151655); InternViT frontend is a STUB (precomputed patch embeds)."""
import jax.numpy as jnp
from repro.configs.common import ArchConfig
from repro.models.api import ModelCfg

ARCH = ArchConfig(
    arch_id="internvl2_1b",
    source="arXiv:2404.16821",
    model=ModelCfg(name="internvl2-1b", family="vlm",
                   n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
                   d_ff=4864, vocab=151655, qkv_bias=True,
                   n_img_tokens=256, dtype=jnp.bfloat16,
                       remat_save_weights=True),
    notes="vlm: 256 stub image tokens prefixed; loss on text only")
