from repro.configs.common import ArchConfig, SHAPES, get_arch, list_archs  # noqa: F401
