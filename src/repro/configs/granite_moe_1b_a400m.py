"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
24L d_model=1024 16H (GQA kv=8) d_ff=512/expert vocab=49155, MoE 32e top-8."""
import jax.numpy as jnp
from repro.configs.common import ArchConfig
from repro.models.api import ModelCfg

ARCH = ArchConfig(
    arch_id="granite_moe_1b_a400m",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    model=ModelCfg(name="granite-moe-1b-a400m", family="moe",
                   n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
                   d_ff=512, vocab=49155, moe_experts=32, moe_topk=8,
                   dtype=jnp.bfloat16),
    notes="fine-grained MoE: 32 small experts, top-8 routing")
