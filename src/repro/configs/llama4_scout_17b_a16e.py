"""llama4-scout-17b-a16e [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16e top-1."""
import jax.numpy as jnp
from repro.configs.common import ArchConfig
from repro.models.api import ModelCfg

ARCH = ArchConfig(
    arch_id="llama4_scout_17b_a16e",
    source="hf:meta-llama/Llama-4-Scout-17B-16E (unverified)",
    model=ModelCfg(name="llama4-scout-17b-a16e", family="moe",
                   n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
                   d_ff=8192, vocab=202048, moe_experts=16, moe_topk=1, moe_ep=True,
                   tie_embeddings=True, dtype=jnp.bfloat16),
    big=True, seq_client_groups=4,
    notes="~109B total / 17B active; early-fusion frontend out of scope "
          "for the LM cells (text backbone per assignment)")
