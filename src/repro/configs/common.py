"""Config schema, shape grid and the architecture registry."""
from __future__ import annotations

import dataclasses
import importlib

import jax.numpy as jnp
from typing import Optional, Tuple

from repro.models.api import ModelCfg


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeCfg("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCfg("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCfg("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCfg("long_500k", "decode", 524_288, 1),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    model: ModelCfg
    source: str                      # public-literature citation tag
    big: bool = False                # True => sequential clients single-pod,
    #                                  per-pod clients multi-pod (replica
    #                                  cannot fit a 16-way model shard)
    seq_client_groups: int = 4       # sequential clients when big
    local_steps: int = 1             # E for the dry-run train step
    client_lr: float = 0.01
    server_lr: float = 1.0
    zsign_z: int = 1                 # 1 = Gaussian, 0 = uniform (z=inf)
    zsign_sigma: float = 0.01
    notes: str = ""

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        m = self.model
        vocab = min(m.vocab, 997)
        d_model = 64
        n_heads = 4
        n_kv = max(1, min(m.n_kv_heads, 2)) if m.n_kv_heads < m.n_heads else 4
        layers = {"hybrid": 8, "xlstm": 4}.get(m.family, 2)
        red = dataclasses.replace(
            m, n_layers=layers, d_model=d_model, n_heads=n_heads,
            n_kv_heads=n_kv, d_ff=0 if m.d_ff == 0 else 128, vocab=vocab,
            moe_experts=min(m.moe_experts, 4) if m.moe_experts else 0,
            moe_topk=min(m.moe_topk, 2) if m.moe_topk else 0,
            sliding_window=min(m.sliding_window, 8) if m.sliding_window else 0,
            n_img_tokens=4 if m.n_img_tokens else 0,
            dtype=jnp.float32)
        return dataclasses.replace(self, model=red)


_ARCH_IDS = [
    "granite_moe_1b_a400m",
    "llama4_scout_17b_a16e",
    "granite_3_8b",
    "qwen2_0_5b",
    "h2o_danube_3_4b",
    "qwen2_5_32b",
    "jamba_1_5_large_398b",
    "xlstm_350m",
    "internvl2_1b",
    "seamless_m4t_large_v2",
]


def list_archs():
    return list(_ARCH_IDS)


def get_arch(arch_id: str) -> ArchConfig:
    arch_id = arch_id.replace("-", "_").replace(".", "_")
    if arch_id not in _ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {_ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.ARCH
