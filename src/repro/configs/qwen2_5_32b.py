"""qwen2.5-32b [hf:Qwen/Qwen2.5-32B; hf]
64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064, QKV bias."""
import jax.numpy as jnp
from repro.configs.common import ArchConfig
from repro.models.api import ModelCfg

ARCH = ArchConfig(
    arch_id="qwen2_5_32b",
    source="hf:Qwen/Qwen2.5-32B",
    model=ModelCfg(name="qwen2.5-32b", family="dense",
                   n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8,
                   d_ff=27648, vocab=152064, qkv_bias=True,
                   tie_embeddings=False, dtype=jnp.bfloat16),
    big=True, seq_client_groups=2,
    notes="32B dense: per-client replica needs >16-way sharding => "
          "sequential clients single-pod, per-pod clients multi-pod")
