"""xlstm-350m [arXiv:2405.04517; unverified]
24L d_model=1024 4H d_ff=0 vocab=50304; sLSTM + mLSTM blocks (3:1)."""
import jax.numpy as jnp
from repro.configs.common import ArchConfig
from repro.models.api import ModelCfg

ARCH = ArchConfig(
    arch_id="xlstm_350m",
    source="arXiv:2405.04517 (unverified)",
    model=ModelCfg(name="xlstm-350m", family="xlstm",
                   n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
                   d_ff=0, vocab=50304, dtype=jnp.bfloat16,
                       remat_save_weights=True),
    notes="recurrent: O(1) decode state => runs long_500k")
