"""granite-3-8b [hf:ibm-granite/granite-3.0-2b-base family; hf]
40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155."""
import jax.numpy as jnp
from repro.configs.common import ArchConfig
from repro.models.api import ModelCfg

ARCH = ArchConfig(
    arch_id="granite_3_8b",
    source="hf:ibm-granite/granite-3.0-8b-base",
    model=ModelCfg(name="granite-3-8b", family="dense",
                   n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
                   d_ff=12800, vocab=49155, dtype=jnp.bfloat16),
    notes="dense GQA")
