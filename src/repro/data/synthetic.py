"""Synthetic data pipelines.

1. Token streams for the LM architectures (deterministic per (client, round)
   so restarts replay identically — fault-tolerance invariant tested in
   tests/test_checkpoint.py).
2. A small non-i.i.d. classification task mirroring the paper's extreme
   label-partitioned MNIST setting (§4.2): Gaussian class clusters, each
   client holding a subset of labels.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenStream:
    """Markov-ish synthetic token source: deterministic, seekable."""
    vocab: int
    seed: int = 0

    def round_batch(self, round_idx: int, layout: tuple, seq: int) -> jnp.ndarray:
        """layout = (groups, n_clients, E, micro). Returns int32 tokens
        (groups, n_clients, E, micro, seq)."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), round_idx)
        return jax.random.randint(key, layout + (seq,), 0, self.vocab,
                                  dtype=jnp.int32)


def gaussian_mixture_task(n_classes: int = 10, dim: int = 64,
                          n_per_class: int = 256, seed: int = 0):
    """Returns (x, y): clustered Gaussian classification data."""
    rng = np.random.RandomState(seed)
    centers = rng.randn(n_classes, dim) * 3.0
    xs, ys = [], []
    for c in range(n_classes):
        xs.append(centers[c] + rng.randn(n_per_class, dim))
        ys.append(np.full(n_per_class, c))
    return (jnp.asarray(np.concatenate(xs), jnp.float32),
            jnp.asarray(np.concatenate(ys), jnp.int32))


def label_partition(y: jnp.ndarray, n_clients: int) -> list:
    """Paper §4.2: extreme non-i.i.d. — each client gets one label's data."""
    y_np = np.asarray(y)
    labels = np.unique(y_np)
    assert len(labels) >= n_clients
    return [np.where(np.isin(y_np, labels[i::n_clients]))[0]
            for i in range(n_clients)]


def dirichlet_partition(y: jnp.ndarray, n_clients: int, alpha: float = 1.0,
                        seed: int = 0) -> list:
    """Paper §4.3 CIFAR-10 setting: per-client label distribution drawn from
    a symmetric Dirichlet(alpha)."""
    rng = np.random.RandomState(seed)
    y_np = np.asarray(y)
    n_classes = int(y_np.max()) + 1
    idx_by_class = [np.where(y_np == c)[0] for c in range(n_classes)]
    for idx in idx_by_class:
        rng.shuffle(idx)
    client_idx = [[] for _ in range(n_clients)]
    for c, idx in enumerate(idx_by_class):
        props = rng.dirichlet([alpha] * n_clients)
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for i, part in enumerate(np.split(idx, cuts)):
            client_idx[i].append(part)
    return [np.concatenate(parts) for parts in client_idx]


def client_batches(x, y, parts, layout, seed: int, round_idx: int):
    """Sample a fed-round batch {x,y} with leading (groups, n, E, micro)."""
    groups, n, E, micro = layout
    rng = np.random.RandomState((seed * 100003 + round_idx) % (2 ** 31))
    bx = np.zeros((groups, n, E, micro, x.shape[-1]), np.float32)
    by = np.zeros((groups, n, E, micro), np.int32)
    for g in range(groups):
        for i in range(n):
            part = parts[(g * n + i) % len(parts)]
            sel = rng.choice(part, size=E * micro, replace=True)
            bx[g, i] = np.asarray(x)[sel].reshape(E, micro, -1)
            by[g, i] = np.asarray(y)[sel].reshape(E, micro)
    return {"x": jnp.asarray(bx), "y": jnp.asarray(by)}
