"""Jamba-style hybrid: attention:mamba 1:7 interleave, MoE on alternate
sublayers (matching Jamba-1.5's every-other-layer MoE; 4 MoE + 4 dense FFN
per 8-sublayer super-block -> 36 MoE layers at 72 total).

Params are stacked over super-blocks (n_layers // 8) and scanned; the 8
sublayers inside a super-block are unrolled (attn at position 0, mamba at
1..7), so HLO size is O(8) regardless of depth.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import mamba as M
from repro.launch.hints import seq_shard, fsdp_params

SUB = 8  # sublayers per super-block: 1 attn + 7 mamba


def _remat_policy(cfg):
    names = ["kv_gathered"] + (["fsdp_gathered"] if cfg.remat_save_weights
                               else [])
    return jax.checkpoint_policies.save_only_these_names(*names)


def init_params(key, cfg):
    nb = cfg.n_layers // SUB
    D, V, dtype = cfg.d_model, cfg.vocab, cfg.dtype
    ks = jax.random.split(key, 8)
    n_moe, n_mlp = SUB // 2, SUB - SUB // 2
    p = {
        "embed": L._init(ks[0], (V, D), scale=0.02, dtype=dtype),
        "attn": L.attn_init(ks[1], cfg.attn_cfg(), nb, dtype),
        "mamba": M.mamba_init(ks[2], D, nb * (SUB - 1), dtype),
        "moe": L.moe_init(ks[3], D, cfg.d_ff, cfg.moe_experts, nb * n_moe, dtype),
        "mlp": L.mlp_init(ks[4], D, cfg.d_ff, nb * n_mlp, dtype),
        "ln_mix": jnp.ones((nb, SUB, D), dtype),
        "ln_ffn": jnp.ones((nb, SUB, D), dtype),
        "lnf": jnp.ones((D,), dtype),
    }
    # restack per super-block: mamba (nb, 7, ...), moe (nb, 4, ...), mlp (nb, 4, ...)
    p["mamba"] = jax.tree.map(lambda w: w.reshape(nb, SUB - 1, *w.shape[1:]), p["mamba"])
    p["moe"] = jax.tree.map(lambda w: w.reshape(nb, n_moe, *w.shape[1:]), p["moe"])
    p["mlp"] = jax.tree.map(lambda w: w.reshape(nb, n_mlp, *w.shape[1:]), p["mlp"])
    if not cfg.tie_embeddings:
        p["lm_head"] = L._init(ks[5], (D, V), scale=0.02, dtype=dtype)
    return p


def _super_block(cfg, x, bp, positions):
    """8 sublayers: [attn, mamba x7]; FFN alternates MoE (even) / MLP (odd)."""
    aux = jnp.zeros((), jnp.float32)
    moe_i = mlp_i = 0
    for s in range(SUB):
        xn = L.rms_norm(x, bp["ln_mix"][s])
        if s == 0:
            mix = L.attention(xn, fsdp_params(bp["attn"], skip=()),
                              cfg.attn_cfg(), positions)
        else:
            lp = jax.tree.map(lambda w: w[s - 1], bp["mamba"])
            # mamba weights stay sharded: the block is channel-parallel
            # (mamba.py docstring), so replicating them would defeat it.
            mix = M.mamba_block(xn, lp, d_model=cfg.d_model)
        x = seq_shard(x + mix)
        hn = L.rms_norm(x, bp["ln_ffn"][s])
        if s % 2 == 0:
            lp = jax.tree.map(lambda w: w[moe_i], bp["moe"])
            y, a = L.moe_apply(hn, lp, cfg.moe_experts, cfg.moe_topk,
                               ep=cfg.moe_ep)
            aux += a
            moe_i += 1
        else:
            lp = jax.tree.map(lambda w: w[mlp_i], bp["mlp"])
            y = L.swiglu(hn, fsdp_params(lp, skip=()))
            mlp_i += 1
        x = seq_shard(x + y)
    return x, aux


def forward_hidden(params, tokens, cfg):
    x = seq_shard(params["embed"][tokens])
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    stack = {k: params[k] for k in ("attn", "mamba", "moe", "mlp", "ln_mix", "ln_ffn")}

    @partial(jax.checkpoint, prevent_cse=False,
             policy=_remat_policy(cfg))
    def body(carry, bp):
        x, aux = carry
        x, a = _super_block(cfg, x, bp, positions)
        return (x, aux + a), ()

    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), stack)
    return L.rms_norm(x, params["lnf"]), aux / (cfg.n_layers // 2)


def _head(params, cfg):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def forward(params, tokens, cfg):
    x, aux = forward_hidden(params, tokens, cfg)
    return (x @ _head(params, cfg)).astype(jnp.float32), aux


def loss_fn(params, batch, cfg):
    x, aux = forward_hidden(params, batch["tokens"], cfg)
    ce = L.chunked_ce(x[:, :-1], _head(params, cfg), batch["tokens"][:, 1:],
                      chunk=cfg.q_chunk)
    return ce + 0.01 * aux


def init_cache(cfg, batch_size: int, max_len: int):
    nb = cfg.n_layers // SUB
    K, hd = cfg.n_kv_heads, cfg.d_head
    mc = M.mamba_cache_init(batch_size, cfg.d_model, nb * (SUB - 1))
    return {
        "k": jnp.zeros((nb, batch_size, max_len, K, hd), cfg.dtype),
        "v": jnp.zeros((nb, batch_size, max_len, K, hd), cfg.dtype),
        "h": mc["h"].reshape(nb, SUB - 1, *mc["h"].shape[1:]),
        "conv": mc["conv"].reshape(nb, SUB - 1, *mc["conv"].shape[1:]),
    }


def decode_step(params, cache, tokens, position, cfg):
    x = params["embed"][tokens]
    stack = {k: params[k] for k in ("attn", "mamba", "moe", "mlp", "ln_mix", "ln_ffn")}

    def body(x, scanned):
        bp, ck, cv, h, conv = scanned
        moe_i = mlp_i = 0
        new_h, new_conv = [], []
        for s in range(SUB):
            xn = L.rms_norm(x, bp["ln_mix"][s])
            if s == 0:
                mix, ck, cv = L.attention_decode(xn, bp["attn"], cfg.attn_cfg(),
                                                 ck, cv, position)
            else:
                lp = jax.tree.map(lambda w: w[s - 1], bp["mamba"])
                mix, h_s, conv_s = M.mamba_decode_step(
                    xn, lp, h[s - 1], conv[s - 1], d_model=cfg.d_model)
                new_h.append(h_s)
                new_conv.append(conv_s)
            x = x + mix
            hn = L.rms_norm(x, bp["ln_ffn"][s])
            if s % 2 == 0:
                lp = jax.tree.map(lambda w: w[moe_i], bp["moe"])
                y, _ = L.moe_apply(hn, lp, cfg.moe_experts, cfg.moe_topk,
                                   ep=cfg.moe_ep)
                moe_i += 1
            else:
                lp = jax.tree.map(lambda w: w[mlp_i], bp["mlp"])
                y = L.swiglu(hn, lp)
                mlp_i += 1
            x = x + y
        return x, (ck, cv, jnp.stack(new_h), jnp.stack(new_conv))

    x, (nk, nv, nh, nconv) = jax.lax.scan(
        body, x, (stack, cache["k"], cache["v"], cache["h"], cache["conv"]))
    x = L.rms_norm(x, params["lnf"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return (x @ head).astype(jnp.float32), {"k": nk, "v": nv, "h": nh, "conv": nconv}
