from repro.models.api import build_model, ModelCfg  # noqa: F401
