"""Encoder-decoder transformer (seamless-m4t style: speech encoder stub +
text decoder with cross-attention).

The modality frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, S_src, D); the encoder is the transformer
stack on top of them. Assigned "24L" is per-stack depth (24 enc + 24 dec),
matching the real w2v-BERT + NLLB layout.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.launch.hints import seq_shard, fsdp_params


def _remat_policy(cfg):
    names = ["kv_gathered"] + (["fsdp_gathered"] if cfg.remat_save_weights
                               else [])
    return jax.checkpoint_policies.save_only_these_names(*names)


def init_params(key, cfg):
    nl, D, V, dtype = cfg.n_layers, cfg.d_model, cfg.vocab, cfg.dtype
    ks = jax.random.split(key, 10)
    p = {
        "embed": L._init(ks[0], (V, D), scale=0.02, dtype=dtype),
        # encoder (bidirectional self-attn)
        "enc_attn": L.attn_init(ks[1], cfg.attn_cfg(), nl, dtype),
        "enc_mlp": L.mlp_init(ks[2], D, cfg.d_ff, nl, dtype),
        "enc_ln1": jnp.ones((nl, D), dtype),
        "enc_ln2": jnp.ones((nl, D), dtype),
        "enc_lnf": jnp.ones((D,), dtype),
        # decoder (causal self-attn + cross-attn)
        "dec_attn": L.attn_init(ks[3], cfg.attn_cfg(), nl, dtype),
        "x_attn": L.attn_init(ks[4], cfg.attn_cfg(), nl, dtype),
        "dec_mlp": L.mlp_init(ks[5], D, cfg.d_ff, nl, dtype),
        "dec_ln1": jnp.ones((nl, D), dtype),
        "dec_ln2": jnp.ones((nl, D), dtype),
        "dec_ln3": jnp.ones((nl, D), dtype),
        "dec_lnf": jnp.ones((D,), dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L._init(ks[6], (D, V), scale=0.02, dtype=dtype)
    return p


def _cross_attention(x, mem_k, mem_v, lp, cfg):
    """x: (B, S_tgt, D) queries over fixed encoder memory K/V (B, S_src, K, hd)."""
    B, S, _ = x.shape
    H, hd = cfg.n_heads, cfg.d_head
    q = (x @ lp["wq"]).reshape(B, S, H, hd)
    rep = H // cfg.n_kv_heads
    k_r = jnp.repeat(mem_k, rep, axis=2)
    v_r = jnp.repeat(mem_v, rep, axis=2)
    scores = jnp.einsum("bchd,bshd->bhcs", q, k_r,
                        preferred_element_type=jnp.float32) / (hd ** 0.5)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    y = jnp.einsum("bhcs,bshd->bchd", probs, v_r).reshape(B, S, H * hd)
    return y @ lp["wo"]


def _mem_kv(mem, lp, cfg):
    B, S, _ = mem.shape
    K, hd = cfg.n_kv_heads, cfg.d_head
    k = (mem @ lp["wk"]).reshape(B, S, K, hd)
    v = (mem @ lp["wv"]).reshape(B, S, K, hd)
    return k, v


def encode(params, embeds, cfg):
    """embeds: (B, S_src, D) stub frame embeddings -> encoder memory."""
    x = seq_shard(embeds.astype(cfg.dtype))
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    bidir = cfg.attn_cfg_bidir()
    stack = {k: params[k] for k in ("enc_attn", "enc_mlp", "enc_ln1", "enc_ln2")}

    @partial(jax.checkpoint, prevent_cse=False,
             policy=_remat_policy(cfg))
    def body(x, lp):
        h = seq_shard(x + L.attention(L.rms_norm(x, lp["enc_ln1"]),
                                      fsdp_params(lp["enc_attn"], skip=()),
                                      bidir, positions))
        return seq_shard(h + L.swiglu(L.rms_norm(h, lp["enc_ln2"]),
                                      fsdp_params(lp["enc_mlp"], skip=()))), ()

    x, _ = jax.lax.scan(body, x, stack)
    return L.rms_norm(x, params["enc_lnf"])


def decode_train(params, mem, tokens, cfg):
    """mem: (B, S_src, D); tokens: (B, S_tgt)."""
    x = seq_shard(params["embed"][tokens])
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    stack = {k: params[k] for k in
             ("dec_attn", "x_attn", "dec_mlp", "dec_ln1", "dec_ln2", "dec_ln3")}

    @partial(jax.checkpoint, prevent_cse=False,
             policy=_remat_policy(cfg))
    def body(x, lp):
        x_attn = fsdp_params(lp["x_attn"], skip=())
        h = seq_shard(x + L.attention(L.rms_norm(x, lp["dec_ln1"]),
                                      fsdp_params(lp["dec_attn"], skip=()),
                                      cfg.attn_cfg(), positions))
        mk, mv = _mem_kv(mem, x_attn, cfg)
        h = seq_shard(h + _cross_attention(L.rms_norm(h, lp["dec_ln2"]),
                                           mk, mv, x_attn, cfg))
        return seq_shard(h + L.swiglu(L.rms_norm(h, lp["dec_ln3"]),
                                      fsdp_params(lp["dec_mlp"], skip=()))), ()

    x, _ = jax.lax.scan(body, x, stack)
    return L.rms_norm(x, params["dec_lnf"])


def _head(params, cfg):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def loss_fn(params, batch, cfg):
    """batch: {embeds (B, S_src, D), tokens (B, S_tgt)}."""
    mem = encode(params, batch["embeds"], cfg)
    x = decode_train(params, mem, batch["tokens"], cfg)
    return L.chunked_ce(x[:, :-1], _head(params, cfg), batch["tokens"][:, 1:],
                        chunk=cfg.q_chunk)


def init_cache(cfg, batch_size: int, max_len: int, src_len: int):
    nl, K, hd = cfg.n_layers, cfg.n_kv_heads, cfg.d_head
    return {"k": jnp.zeros((nl, batch_size, max_len, K, hd), cfg.dtype),
            "v": jnp.zeros((nl, batch_size, max_len, K, hd), cfg.dtype),
            "mem_k": jnp.zeros((nl, batch_size, src_len, K, hd), cfg.dtype),
            "mem_v": jnp.zeros((nl, batch_size, src_len, K, hd), cfg.dtype)}


def prefill_memory(params, embeds, cfg):
    """Run the encoder once and pre-project per-layer cross K/V."""
    mem = encode(params, embeds, cfg)

    def proj(lp):
        return _mem_kv(mem, lp, cfg)

    ks, vs = jax.vmap(proj)({"wk": params["x_attn"]["wk"],
                             "wv": params["x_attn"]["wv"]})
    return ks, vs


def decode_step(params, cache, tokens, position, cfg):
    x = params["embed"][tokens]
    stack = {k: params[k] for k in
             ("dec_attn", "x_attn", "dec_ln1", "dec_ln2", "dec_ln3", "dec_mlp")}

    def body(x, scanned):
        lp, ck, cv, mk, mv = scanned
        y, ck, cv = L.attention_decode(L.rms_norm(x, lp["dec_ln1"]),
                                       lp["dec_attn"], cfg.attn_cfg(),
                                       ck, cv, position)
        h = x + y
        h = h + _cross_attention(L.rms_norm(h, lp["dec_ln2"]), mk, mv,
                                 lp["x_attn"], cfg)
        h = h + L.swiglu(L.rms_norm(h, lp["dec_ln3"]), lp["dec_mlp"])
        return h, (ck, cv)

    x, (nk, nv) = jax.lax.scan(
        body, x, (stack, cache["k"], cache["v"], cache["mem_k"], cache["mem_v"]))
    x = L.rms_norm(x, params["dec_lnf"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    new_cache = dict(cache, k=nk, v=nv)
    return (x @ head).astype(jnp.float32), new_cache
