"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallel train
form) and sLSTM (scalar memory, strictly recurrent).

mLSTM training uses the attention-like parallel formulation with a
stabilized log-gate decay matrix (quadratic in the chunk, chunked over
sequence); decode is the O(1) matrix-memory recurrence.  sLSTM trains with a
chunked sequential scan (no parallel form exists — paper's own statement).
The assigned xlstm-350m config (d_ff = 0) means blocks carry their own
up/down projections (proj factor 2), no separate FFN — noted in DESIGN.md.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models.layers import _init, rms_norm
from repro.launch.hints import seq_shard, fsdp_params

CHUNK = 256


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, d_model: int, n_heads: int, n_layers: int, dtype):
    ks = jax.random.split(key, 6)
    return {
        "wqkv": _init(ks[0], (n_layers, d_model, 3 * d_model), dtype=dtype),
        "wif": _init(ks[1], (n_layers, d_model, 2 * n_heads), scale=0.02, dtype=dtype),
        "bif": jnp.zeros((n_layers, 2 * n_heads), jnp.float32),
        "wo": _init(ks[2], (n_layers, d_model, d_model), dtype=dtype),
        "ln_sk": jnp.ones((n_layers, d_model), dtype),
    }


def _mlstm_gates(x, lp, n_heads):
    gif = x.astype(jnp.float32) @ lp["wif"].astype(jnp.float32) + lp["bif"]
    i_pre, f_pre = jnp.split(gif, 2, axis=-1)         # (B, T, H)
    log_f = -jax.nn.softplus(-f_pre)                  # log sigmoid(f)
    return i_pre, log_f


def mlstm_block(x, lp, *, n_heads: int):
    """Parallel (chunk-quadratic) mLSTM forward. x: (B, T, D)."""
    B, T, D = x.shape
    H, hd = n_heads, D // n_heads
    qkv = x @ lp["wqkv"]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(B, T, H, hd).swapaxes(1, 2)         # (B, H, T, hd)
    k = k.reshape(B, T, H, hd).swapaxes(1, 2) / (hd ** 0.5)
    v = v.reshape(B, T, H, hd).swapaxes(1, 2)
    i_pre, log_f = _mlstm_gates(x, lp, H)             # (B, T, H)
    i_pre = i_pre.swapaxes(1, 2)                      # (B, H, T)
    log_f = log_f.swapaxes(1, 2)
    F = jnp.cumsum(log_f, axis=-1)                    # (B, H, T) log prod f

    # D[t,s] = exp(F_t - F_s + i_s), s <= t. Flash-style: chunk over the
    # KEY axis with an online running max — queries / F stay sequence-
    # sharded, keys+gates are gathered (sharding-transparent chunking, same
    # rationale as layers._flash_kv_attention: chunking the SHARDED q dim
    # forces full-activation gathers).
    from repro.launch import hints as HN
    kc = min(CHUNK, T)
    if T % kc != 0:
        kc = T
    nc = T // kc
    qf = q.astype(jnp.float32)                        # (B, H, T, hd)
    k_g, v_g, F_g, i_g = HN.opt_barrier(
        (HN.gather_seq(k.swapaxes(1, 2)),             # (B, T, H, hd)
         HN.gather_seq(v.swapaxes(1, 2)),
         HN.gather_seq(F.swapaxes(1, 2)),             # (B, T, H)
         HN.gather_seq(i_pre.swapaxes(1, 2))))
    kt = k_g.reshape(B, nc, kc, H, hd).swapaxes(0, 1)
    vt = v_g.reshape(B, nc, kc, H, hd).swapaxes(0, 1)
    Ft = F_g.reshape(B, nc, kc, H).swapaxes(0, 1)
    it = i_g.reshape(B, nc, kc, H).swapaxes(0, 1)
    t_pos = jnp.arange(T)
    pos_t = t_pos.reshape(nc, kc)

    def body(carry, xs):
        m_prev, num, den = carry          # m/den (B,H,T); num (B,H,T,hd)
        k_c, v_c, F_c, i_c, kp = xs
        # F_c/i_c: (B, kc, H) -> (B, H, 1, kc)
        expo = (F[..., :, None]
                - F_c.transpose(0, 2, 1)[..., None, :]
                + i_c.transpose(0, 2, 1)[..., None, :])      # (B,H,T,kc)
        mask = t_pos[:, None] >= kp[None, :]
        expo = jnp.where(mask[None, None], expo, -jnp.inf)
        m_new = jnp.maximum(jnp.maximum(m_prev, jnp.max(expo, axis=-1)),
                            -1e30)
        w = jnp.exp(expo - m_new[..., None])
        qk = jnp.einsum("bhtd,bshd->bhts", qf, k_c.astype(jnp.float32))
        sc = qk * w
        scale = jnp.exp(m_prev - m_new)
        num = num * scale[..., None] + jnp.einsum(
            "bhts,bshd->bhtd", sc, v_c.astype(jnp.float32))
        den = den * scale + jnp.sum(sc, axis=-1)
        return (m_new, num, den), ()

    m0 = jnp.full((B, H, T), -1e30, jnp.float32)
    num0 = jnp.zeros((B, H, T, hd), jnp.float32)
    den0 = jnp.zeros((B, H, T), jnp.float32)
    (m, num, den), _ = jax.lax.scan(body, (m0, num0, den0),
                                    (kt, vt, Ft, it, pos_t))
    y = num / jnp.maximum(jnp.abs(den), jnp.exp(-m))[..., None]
    y = y.swapaxes(1, 2).reshape(B, T, D).astype(x.dtype)
    y = rms_norm(y, lp["ln_sk"])
    return y @ lp["wo"]


def mlstm_cache_init(batch, d_model, n_heads, n_layers):
    hd = d_model // n_heads
    return {"C": jnp.zeros((n_layers, batch, n_heads, hd, hd), jnp.float32),
            "n": jnp.zeros((n_layers, batch, n_heads, hd), jnp.float32),
            "m": jnp.full((n_layers, batch, n_heads), -1e30, jnp.float32)}


def mlstm_decode_step(x, lp, C, n, m, *, n_heads: int):
    """O(1) recurrent step. x: (B, 1, D)."""
    B, _, D = x.shape
    H, hd = n_heads, D // n_heads
    qkv = x @ lp["wqkv"]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(B, H, hd).astype(jnp.float32)
    k = (k.reshape(B, H, hd) / (hd ** 0.5)).astype(jnp.float32)
    v = v.reshape(B, H, hd).astype(jnp.float32)
    i_pre, log_f = _mlstm_gates(x, lp, H)
    i_pre, log_f = i_pre[:, 0], log_f[:, 0]           # (B, H)
    m_new = jnp.maximum(log_f + m, i_pre)
    dec = jnp.exp(log_f + m - m_new)[..., None]
    inp = jnp.exp(i_pre - m_new)[..., None]
    C = dec[..., None] * C + (inp * k)[..., :, None] * v[..., None, :]
    n = dec * n + inp * k
    num = jnp.einsum("bhd,bhde->bhe", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n)),
                      jnp.exp(-m_new))[..., None]
    y = (num / den).reshape(B, 1, D).astype(x.dtype)
    y = rms_norm(y, lp["ln_sk"])
    return y @ lp["wo"], C, n, m_new


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, d_model: int, n_heads: int, n_layers: int, dtype):
    ks = jax.random.split(key, 3)
    hd = d_model // n_heads
    return {
        "wx": _init(ks[0], (n_layers, d_model, 4 * d_model), dtype=dtype),
        # block-diagonal recurrent weights, one (hd, 4*hd) block per head
        "wr": _init(ks[1], (n_layers, n_heads, hd, 4 * hd), scale=hd ** -0.5,
                    dtype=jnp.float32),
        "b": jnp.zeros((n_layers, 4 * d_model), jnp.float32),
        "wo": _init(ks[2], (n_layers, d_model, d_model), dtype=dtype),
        "ln_sk": jnp.ones((n_layers, d_model), dtype),
    }


def _slstm_step(carry, xs, wr, n_heads):
    (h, c, n, m) = carry          # each (B, D) / m,n: (B, D)
    x_t = xs                      # (B, 4D) pre-activation from input
    B, D = h.shape
    hd = D // n_heads
    h_heads = h.reshape(B, n_heads, hd)
    rec = jnp.einsum("bkh,khf->bkf", h_heads, wr).reshape(B, 4 * D)
    z_pre, i_pre, f_pre, o_pre = jnp.split(x_t + rec, 4, axis=-1)
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    log_f = -jax.nn.softplus(-f_pre)
    m_new = jnp.maximum(log_f + m, i_pre)
    i = jnp.exp(i_pre - m_new)
    f = jnp.exp(log_f + m - m_new)
    c = f * c + i * z
    n = jnp.maximum(f * n + i, jnp.exp(-m_new))
    h_new = o * (c / n)
    return (h_new, c, n, m_new), h_new


def slstm_block(x, lp, *, n_heads: int):
    """Chunked sequential sLSTM. x: (B, T, D).

    The recurrence is strictly sequential over T and couples all channels of
    a head — it cannot be sequence- or (16-way) channel-parallel. A/B
    measured: gathering x_pre once per layer (fp32, 1 GB) LOSES to letting
    the scan dynamic-slice-gather per chunk (54.5 vs 48 GB/dev total), so
    the per-chunk form is kept."""
    B, T, D = x.shape
    x_pre = (x @ lp["wx"]).astype(jnp.float32) + lp["b"]        # (B, T, 4D)
    zeros = jnp.zeros((B, D), jnp.float32)
    carry0 = (zeros, zeros, zeros + 1e-6, jnp.full((B, D), -1e30, jnp.float32))
    n_chunks = max(1, T // CHUNK)
    c = T // n_chunks
    xc = x_pre.reshape(B, n_chunks, c, 4 * D).swapaxes(0, 1).swapaxes(1, 2)

    step = partial(_slstm_step, wr=lp["wr"].astype(jnp.float32), n_heads=n_heads)

    @partial(jax.checkpoint, prevent_cse=False)
    def chunk(carry, x_chunk):
        return jax.lax.scan(step, carry, x_chunk)

    _, h = jax.lax.scan(chunk, carry0, xc)                      # (nc, c, B, D)
    h = h.reshape(T, B, D).swapaxes(0, 1).astype(x.dtype)
    h = rms_norm(h, lp["ln_sk"])
    return h @ lp["wo"]


def slstm_cache_init(batch, d_model, n_layers):
    z = jnp.zeros((n_layers, batch, d_model), jnp.float32)
    return {"h": z, "c": z, "n": z + 1e-6,
            "m": jnp.full((n_layers, batch, d_model), -1e30, jnp.float32)}


def slstm_decode_step(x, lp, h, c, n, m, *, n_heads: int):
    x_pre = (x[:, 0] @ lp["wx"]).astype(jnp.float32) + lp["b"]
    (h, c, n, m), h_out = _slstm_step((h, c, n, m), x_pre,
                                      lp["wr"].astype(jnp.float32), n_heads)
    y = rms_norm(h_out[:, None, :].astype(x.dtype), lp["ln_sk"])
    return y @ lp["wo"], h, c, n, m


# ---------------------------------------------------------------------------
# full xLSTM LM: super-blocks of 4 (3 mLSTM + 1 sLSTM), scanned over depth.
# d_ff == 0 in the assigned config: blocks carry their own projections.
# ---------------------------------------------------------------------------

GROUP = 4  # 3 mLSTM + 1 sLSTM per super-block


def init_params(key, cfg):
    ng = cfg.n_layers // GROUP
    D, V, H, dtype = cfg.d_model, cfg.vocab, cfg.n_heads, cfg.dtype
    ks = jax.random.split(key, 5)
    p = {
        "embed": _init(ks[0], (V, D), scale=0.02, dtype=dtype),
        "mlstm": mlstm_init(ks[1], D, H, ng * (GROUP - 1), dtype),
        "slstm": slstm_init(ks[2], D, H, ng, dtype),
        "ln": jnp.ones((ng, GROUP, D), dtype),
        "lnf": jnp.ones((D,), dtype),
    }
    p["mlstm"] = jax.tree.map(lambda w: w.reshape(ng, GROUP - 1, *w.shape[1:]),
                              p["mlstm"])
    if not cfg.tie_embeddings:
        p["lm_head"] = _init(ks[3], (D, V), scale=0.02, dtype=dtype)
    return p


def _group_fwd(cfg, x, gp):
    for s in range(GROUP):
        xn = rms_norm(x, gp["ln"][s])
        if s < GROUP - 1:
            lp = jax.tree.map(lambda w: w[s], gp["mlstm"])
            x = seq_shard(x + mlstm_block(xn, fsdp_params(lp, skip=()),
                                          n_heads=cfg.n_heads))
        else:
            x = seq_shard(x + slstm_block(xn, fsdp_params(gp["slstm"], skip=()),
                                          n_heads=cfg.n_heads))
    return x


def forward_hidden(params, tokens, cfg):
    x = seq_shard(params["embed"][tokens])
    stack = {k: params[k] for k in ("mlstm", "slstm", "ln")}

    @partial(jax.checkpoint, prevent_cse=False)
    def body(x, gp):
        return _group_fwd(cfg, x, gp), ()

    x, _ = jax.lax.scan(body, x, stack)
    return rms_norm(x, params["lnf"])


def _head(params, cfg):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def forward(params, tokens, cfg):
    return (forward_hidden(params, tokens, cfg) @ _head(params, cfg)
            ).astype(jnp.float32)


def loss_fn(params, batch, cfg):
    from repro.models.layers import chunked_ce
    x = forward_hidden(params, batch["tokens"], cfg)
    return chunked_ce(x[:, :-1], _head(params, cfg), batch["tokens"][:, 1:],
                      chunk=cfg.q_chunk)


def init_cache(cfg, batch_size: int, max_len: int):
    del max_len  # recurrent: O(1) state
    ng = cfg.n_layers // GROUP
    mc = mlstm_cache_init(batch_size, cfg.d_model, cfg.n_heads, ng * (GROUP - 1))
    sc = slstm_cache_init(batch_size, cfg.d_model, ng)
    mc = jax.tree.map(lambda w: w.reshape(ng, GROUP - 1, *w.shape[1:]), mc)
    return {"m": mc, "s": sc}


def decode_step(params, cache, tokens, position, cfg):
    del position
    x = params["embed"][tokens]
    stack = {k: params[k] for k in ("mlstm", "slstm", "ln")}

    def body(x, scanned):
        gp, mC, mn, mm, sh, sc_, sn, sm = scanned
        new_m = {"C": [], "n": [], "m": []}
        for s in range(GROUP):
            xn = rms_norm(x, gp["ln"][s])
            if s < GROUP - 1:
                lp = jax.tree.map(lambda w: w[s], gp["mlstm"])
                y, C, n, m = mlstm_decode_step(xn, lp, mC[s], mn[s], mm[s],
                                               n_heads=cfg.n_heads)
                new_m["C"].append(C); new_m["n"].append(n); new_m["m"].append(m)
                x = x + y
            else:
                y, sh, sc_, sn, sm = slstm_decode_step(xn, gp["slstm"],
                                                       sh, sc_, sn, sm,
                                                       n_heads=cfg.n_heads)
                x = x + y
        return x, (jnp.stack(new_m["C"]), jnp.stack(new_m["n"]),
                   jnp.stack(new_m["m"]), sh, sc_, sn, sm)

    x, (C, n, m, sh, sc_, sn, sm) = jax.lax.scan(
        body, x, (stack, cache["m"]["C"], cache["m"]["n"], cache["m"]["m"],
                  cache["s"]["h"], cache["s"]["c"], cache["s"]["n"],
                  cache["s"]["m"]))
    x = rms_norm(x, params["lnf"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    new_cache = {"m": {"C": C, "n": n, "m": m},
                 "s": {"h": sh, "c": sc_, "n": sn, "m": sm}}
    return (x @ head).astype(jnp.float32), new_cache
