"""Selective SSM (Mamba-1 style) block, TPU-adapted.

GPU Mamba fuses the selective scan in a CUDA kernel; here the TPU-native
formulation is a *chunked* scan: outer ``lax.scan`` over time chunks carrying
the (B, d_inner, d_state) hidden state, inner ``lax.scan`` over steps within
the chunk, with remat per chunk — peak activation memory is one chunk of
states instead of the full sequence (see DESIGN.md §2).  Decode is the O(1)
single-step recurrence on the carried state.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models.layers import _init, rms_norm

D_STATE = 16
D_CONV = 4
CHUNK = 256


def mamba_init(key, d_model: int, n_layers: int, dtype, expand: int = 2):
    d_in = expand * d_model
    ks = jax.random.split(key, 7)
    dt_rank = max(1, d_model // 16)
    return {
        "in_proj": _init(ks[0], (n_layers, d_model, 2 * d_in), dtype=dtype),
        "conv_w": _init(ks[1], (n_layers, D_CONV, d_in), scale=0.5, dtype=dtype),
        "x_proj": _init(ks[2], (n_layers, d_in, dt_rank + 2 * D_STATE), dtype=dtype),
        "dt_proj": _init(ks[3], (n_layers, dt_rank, d_in), scale=dt_rank ** -0.5, dtype=dtype),
        "dt_bias": jnp.zeros((n_layers, d_in), dtype),
        "a_log": jnp.broadcast_to(
            jnp.log(jnp.arange(1, D_STATE + 1, dtype=jnp.float32)),
            (n_layers, d_in, D_STATE)).astype(jnp.float32),
        "d_skip": jnp.ones((n_layers, d_in), jnp.float32),
        "out_proj": _init(ks[4], (n_layers, d_in, d_model), dtype=dtype),
    }


def _ssm_params(x_in, lp, dt_rank):
    """x_in: (B, T, d_in) -> dt (B,T,d_in), B_/C_ (B,T,d_state)."""
    proj = x_in @ lp["x_proj"]
    dt_low, B_, C_ = jnp.split(proj, [dt_rank, dt_rank + D_STATE], axis=-1)
    dt = jax.nn.softplus(dt_low @ lp["dt_proj"] + lp["dt_bias"])
    return dt.astype(jnp.float32), B_.astype(jnp.float32), C_.astype(jnp.float32)


def _scan_chunked(dt, B_, C_, x, a_log, h0):
    """Selective scan. dt/x: (B, T, d_in); B_/C_: (B, T, N); h0: (B, d_in, N).
    Returns y (B, T, d_in), hT."""
    Bsz, T, d_in = x.shape
    A = -jnp.exp(a_log)  # (d_in, N)
    n_chunks = max(1, T // CHUNK)
    c = T // n_chunks

    def inner_step(h, xs):
        dt_t, b_t, c_t, x_t = xs  # (B,d_in), (B,N), (B,N), (B,d_in)
        da = jnp.exp(dt_t[..., None] * A)                       # (B, d_in, N)
        h = da * h + (dt_t * x_t)[..., None] * b_t[:, None, :]  # (B, d_in, N)
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    @partial(jax.checkpoint, prevent_cse=False)
    def chunk_step(h, xs):
        dt_c, b_c, c_c, x_c = xs  # (c, B, ...)
        h, y_c = jax.lax.scan(inner_step, h, (dt_c, b_c, c_c, x_c))
        return h, y_c

    def tchunks(z):
        # (B, T, ...) -> (n_chunks, c, B, ...)
        return z.reshape(Bsz, n_chunks, c, *z.shape[2:]).swapaxes(0, 1).swapaxes(1, 2)

    hT, y = jax.lax.scan(chunk_step, h0,
                         (tchunks(dt), tchunks(B_), tchunks(C_),
                          tchunks(x.astype(jnp.float32))))
    y = y.reshape(n_chunks * c, Bsz, d_in).swapaxes(0, 1)       # (B, T, d_in)
    return y, hT


def _causal_conv(x, w):
    """depthwise causal conv. x: (B, T, d_in); w: (K, d_in)."""
    pads = [(0, 0), (D_CONV - 1, 0), (0, 0)]
    xp = jnp.pad(x, pads)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(D_CONV))
    return out


def mamba_block(x, lp, *, d_model: int):
    """x: (B, T, D) -> (B, T, D). Training forward.

    Distribution: the time recurrence cannot be sequence-parallel, but it IS
    embarrassingly channel-parallel. Inside the block the sequence dim is
    therefore REPLICATED (one ~0.5 GB bf16 gather per layer on jamba) and
    d_inner is sharded over `model`; the output projection reduce-scatters
    back to the sequence-sharded residual stream. Naively scanning over a
    sharded time dim instead costs 17.7 TB/dev of collectives (measured,
    EXPERIMENTS.md §Perf jamba iteration 1).
    """
    from repro.launch import hints as H
    d_in = lp["in_proj"].shape[-1] // 2
    dt_rank = lp["dt_proj"].shape[0]
    seq_par = x.shape[1] > 1
    if seq_par:
        x = H.opt_barrier(H.gather_seq(x))
    xz = x @ lp["in_proj"]
    if seq_par:
        xz = H.shard_dim(xz, 2, ("model",))     # channel-parallel from here
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_in = jax.nn.silu(_causal_conv(x_in, lp["conv_w"]))
    dt, B_, C_ = _ssm_params(x_in, lp, dt_rank)
    if seq_par:
        dt = H.shard_dim(dt, 2, ("model",))
    h0 = jnp.zeros((x.shape[0], d_in, D_STATE), jnp.float32)
    y, _ = _scan_chunked(dt, B_, C_, x_in, lp["a_log"], h0)
    y = y + x_in.astype(jnp.float32) * lp["d_skip"]
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = y @ lp["out_proj"]
    if seq_par:
        out = H.seq_shard(out, 1)               # reduce-scatter to seq-sharded
    return out


def mamba_cache_init(batch: int, d_model: int, n_layers: int, expand: int = 2):
    d_in = expand * d_model
    return {"h": jnp.zeros((n_layers, batch, d_in, D_STATE), jnp.float32),
            "conv": jnp.zeros((n_layers, batch, D_CONV - 1, d_in), jnp.float32)}


def mamba_decode_step(x, lp, h, conv_tail, *, d_model: int):
    """One-token recurrence. x: (B, 1, D); h: (B, d_in, N);
    conv_tail: (B, D_CONV-1, d_in). Returns (y, h, conv_tail)."""
    d_in = lp["in_proj"].shape[-1] // 2
    dt_rank = lp["dt_proj"].shape[0]
    xz = x @ lp["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)                         # (B, 1, d_in)
    window = jnp.concatenate([conv_tail, x_in.astype(jnp.float32)], axis=1)
    conv_out = jnp.einsum("bkd,kd->bd", window, lp["conv_w"].astype(jnp.float32))
    x_c = jax.nn.silu(conv_out)[:, None, :]                     # (B, 1, d_in)
    dt, B_, C_ = _ssm_params(x_c.astype(x.dtype), lp, dt_rank)
    A = -jnp.exp(lp["a_log"])
    da = jnp.exp(dt[:, 0, :, None] * A)
    h = da * h + (dt[:, 0] * x_c[:, 0].astype(jnp.float32))[..., None] * B_[:, 0, None, :]
    y = jnp.einsum("bdn,bn->bd", h, C_[:, 0])
    y = y + x_c[:, 0].astype(jnp.float32) * lp["d_skip"]
    y = (y[:, None, :].astype(x.dtype)) * jax.nn.silu(z)
    return y @ lp["out_proj"], h, window[:, 1:]
