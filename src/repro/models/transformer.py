"""Decoder-only transformer LM (dense or MoE), GQA + RoPE + optional SWA.

Params are stacked over depth; forward is lax.scan over layers with
jax.checkpoint (remat) per layer.  Provides train loss and one-token decode.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.launch.hints import seq_shard, fsdp_params


def _remat_policy(cfg):
    names = ["kv_gathered"] + (["fsdp_gathered"] if cfg.remat_save_weights
                               else [])
    return jax.checkpoint_policies.save_only_these_names(*names)


def init_params(key, cfg) -> Dict[str, Any]:
    ks = jax.random.split(key, 6)
    D, V, nl = cfg.d_model, cfg.vocab, cfg.n_layers
    dtype = cfg.dtype
    p = {
        "embed": L._init(ks[0], (V, D), scale=0.02, dtype=dtype),
        "attn": L.attn_init(ks[1], cfg.attn_cfg(), nl, dtype),
        "ln1": jnp.ones((nl, D), dtype),
        "ln2": jnp.ones((nl, D), dtype),
        "lnf": jnp.ones((D,), dtype),
    }
    if cfg.moe_experts > 0:
        p["moe"] = L.moe_init(ks[2], D, cfg.d_ff, cfg.moe_experts, nl, dtype)
    else:
        p["mlp"] = L.mlp_init(ks[2], D, cfg.d_ff, nl, dtype)
    if not cfg.tie_embeddings:
        p["lm_head"] = L._init(ks[3], (D, V), scale=0.02, dtype=dtype)
    return p


def _layer(cfg, x, lp, positions):
    """One transformer block. x: (B, S, D)."""
    lp = dict(lp)
    lp["attn"] = fsdp_params(lp["attn"], skip=())
    if cfg.moe_experts == 0:
        lp["mlp"] = fsdp_params(lp["mlp"], skip=())
    h = x + L.attention(L.rms_norm(x, lp["ln1"]), lp["attn"], cfg.attn_cfg(), positions)
    h = seq_shard(h)
    hn = L.rms_norm(h, lp["ln2"])
    if cfg.moe_experts > 0:
        y, aux = L.moe_apply(hn, lp["moe"], cfg.moe_experts, cfg.moe_topk,
                             ep=cfg.moe_ep)
    else:
        y, aux = L.swiglu(hn, lp["mlp"]), 0.0
    return seq_shard(h + y), aux


def _stacked_layer_params(params, cfg):
    lp = {"attn": params["attn"], "ln1": params["ln1"], "ln2": params["ln2"]}
    lp["moe" if cfg.moe_experts > 0 else "mlp"] = params[
        "moe" if cfg.moe_experts > 0 else "mlp"]
    return lp


def forward_hidden(params, tokens, cfg, *, embeds: jnp.ndarray | None = None):
    """Returns final-norm hidden states (B, S, D) and MoE aux loss."""
    x = params["embed"][tokens] if embeds is None else embeds.astype(cfg.dtype)
    x = seq_shard(x)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    lp_stack = _stacked_layer_params(params, cfg)

    @partial(jax.checkpoint, prevent_cse=False,
             policy=_remat_policy(cfg))
    def body(carry, lp):
        x, aux = carry
        x, a = _layer(cfg, x, lp, positions)
        return (x, aux + a), ()

    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), lp_stack)
    return L.rms_norm(x, params["lnf"]), aux / cfg.n_layers


def lm_head(params, cfg):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def forward(params, tokens, cfg, *, embeds: jnp.ndarray | None = None):
    """Full logits (tests / small shapes only — O(S*V) memory)."""
    x, aux = forward_hidden(params, tokens, cfg, embeds=embeds)
    return (x @ lm_head(params, cfg)).astype(jnp.float32), aux


def loss_fn(params, batch, cfg):
    """Next-token CE, sequence-chunked (never materializes full logits)."""
    tokens = batch["tokens"]
    embeds = batch.get("embeds")
    x, aux = forward_hidden(params, tokens, cfg, embeds=embeds)
    mask = batch.get("loss_mask")
    mask = mask[:, 1:].astype(jnp.float32) if mask is not None else None
    ce = L.chunked_ce(x[:, :-1], lm_head(params, cfg), tokens[:, 1:], mask,
                      chunk=cfg.q_chunk)
    return ce + 0.01 * aux


# ---------------------------------------------------------------------------
# decode (serving)
# ---------------------------------------------------------------------------

def init_cache(cfg, batch_size: int, max_len: int):
    K, hd, nl = cfg.n_kv_heads, cfg.d_head, cfg.n_layers
    kv_dtype = cfg.dtype
    return {"k": jnp.zeros((nl, batch_size, max_len, K, hd), kv_dtype),
            "v": jnp.zeros((nl, batch_size, max_len, K, hd), kv_dtype)}


def decode_step(params, cache, tokens, position, cfg):
    """One decode step. tokens: (B, 1) int32; position: scalar int32.
    Returns (logits (B, 1, V), new_cache)."""
    x = params["embed"][tokens]
    lp_stack = _stacked_layer_params(params, cfg)

    def body(x, scanned):
        lp, ck, cv = scanned
        y, ck, cv = L.attention_decode(L.rms_norm(x, lp["ln1"]), lp["attn"],
                                       cfg.attn_cfg(), ck, cv, position)
        h = x + y
        hn = L.rms_norm(h, lp["ln2"])
        if cfg.moe_experts > 0:
            y, _ = L.moe_apply(hn, lp["moe"], cfg.moe_experts, cfg.moe_topk,
                               ep=cfg.moe_ep)
        else:
            y = L.swiglu(hn, lp["mlp"])
        return h + y, (ck, cv)

    x, (new_k, new_v) = jax.lax.scan(body, x, (lp_stack, cache["k"], cache["v"]))
    x = L.rms_norm(x, params["lnf"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return (x @ head).astype(jnp.float32), {"k": new_k, "v": new_v}
