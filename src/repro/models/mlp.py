"""Small MLP classifier used by the paper-figure benchmarks and examples
(stands in for the paper's 2-layer CNN — same scale, pure JAX)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def mlp_loss_builder(dim, n_classes, width=64):
    """Small MLP classifier (stands in for the paper's 2-layer CNN — same
    scale, pure-JAX) on {x, y} batches."""
    def init(key):
        k1, k2, k3 = jax.random.split(key, 3)
        return {"w1": jax.random.normal(k1, (dim, width)) / np.sqrt(dim),
                "b1": jnp.zeros(width),
                "w2": jax.random.normal(k2, (width, width)) / np.sqrt(width),
                "b2": jnp.zeros(width),
                "w3": jax.random.normal(k3, (width, n_classes)) / np.sqrt(width),
                "b3": jnp.zeros(n_classes)}

    def logits_fn(p, x):
        h = jax.nn.relu(x @ p["w1"] + p["b1"])
        h = jax.nn.relu(h @ p["w2"] + p["b2"])
        return h @ p["w3"] + p["b3"]

    def loss_fn(p, batch):
        lg = logits_fn(p, batch["x"])
        lp = jax.nn.log_softmax(lg)
        oh = jax.nn.one_hot(batch["y"], n_classes)
        return -jnp.mean(jnp.sum(lp * oh, axis=-1))

    def acc_fn(p, x, y):
        return float(jnp.mean(jnp.argmax(logits_fn(p, x), -1) == y))

    return init, loss_fn, acc_fn
