"""Unified model API: ModelCfg + build_model -> ModelBundle.

ModelBundle is what the federated engine, launcher, dry-run and tests
consume: init / loss_fn / decode_step / init_cache / per-step input specs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class ModelCfg:
    name: str
    family: str                 # dense | moe | hybrid | xlstm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    moe_experts: int = 0
    moe_topk: int = 0
    moe_ep: bool = False   # expert-parallel (big experts) vs replicated
    qkv_bias: bool = False
    sliding_window: int = 0
    tie_embeddings: bool = True
    rope_theta: float = 1e4
    dtype: Any = jnp.float32
    n_img_tokens: int = 0       # vlm stub prefix length
    src_frac: float = 0.5       # encdec: fraction of seq_len used as source
    q_chunk: int = 512
    remat_save_weights: bool = False  # keep FSDP-gathered layer weights across
    #   remat: 1/3 less gather traffic for +L*layer_bytes HBM — only viable
    #   when per-layer weights are small (see EXPERIMENTS.md §Perf)

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    def attn_cfg(self) -> L.AttnCfg:
        return L.AttnCfg(d_model=self.d_model, n_heads=self.n_heads,
                         n_kv_heads=self.n_kv_heads, d_head=self.d_head,
                         qkv_bias=self.qkv_bias,
                         sliding_window=self.sliding_window,
                         rope_theta=self.rope_theta, q_chunk=self.q_chunk)

    def attn_cfg_bidir(self) -> L.AttnCfg:
        return dataclasses.replace(self.attn_cfg(), causal=False,
                                   sliding_window=0)

    def param_count(self, params) -> int:
        return sum(p.size for p in jax.tree_util.tree_leaves(params))


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: ModelCfg
    init: Callable                # (key) -> params
    loss_fn: Callable             # (params, batch) -> scalar
    decode_step: Callable         # (params, cache, tokens, position) -> (logits, cache)
    init_cache: Callable          # (batch, max_len) -> cache
    train_batch_spec: Callable    # (micro_batch, seq_len) -> pytree of ShapeDtypeStruct
    decode_supported: bool = True
    subquadratic: bool = False    # eligible for long_500k


def _lm_specs(cfg: ModelCfg):
    def spec(micro, seq):
        return {"tokens": jax.ShapeDtypeStruct((micro, seq), jnp.int32)}
    return spec


def build_model(cfg: ModelCfg) -> ModelBundle:
    if cfg.family in ("dense", "moe"):
        from repro.models import transformer as T
        return ModelBundle(
            cfg=cfg,
            init=lambda key: T.init_params(key, cfg),
            loss_fn=lambda p, b: T.loss_fn(p, b, cfg),
            decode_step=lambda p, c, t, pos: T.decode_step(p, c, t, pos, cfg),
            init_cache=lambda b, m: T.init_cache(cfg, b, m),
            train_batch_spec=_lm_specs(cfg),
            subquadratic=cfg.sliding_window > 0)

    if cfg.family == "hybrid":
        from repro.models import hybrid as Hy
        return ModelBundle(
            cfg=cfg,
            init=lambda key: Hy.init_params(key, cfg),
            loss_fn=lambda p, b: Hy.loss_fn(p, b, cfg),
            decode_step=lambda p, c, t, pos: Hy.decode_step(p, c, t, pos, cfg),
            init_cache=lambda b, m: Hy.init_cache(cfg, b, m),
            train_batch_spec=_lm_specs(cfg),
            subquadratic=True)

    if cfg.family == "xlstm":
        from repro.models import xlstm as X
        return ModelBundle(
            cfg=cfg,
            init=lambda key: X.init_params(key, cfg),
            loss_fn=lambda p, b: X.loss_fn(p, b, cfg),
            decode_step=lambda p, c, t, pos: X.decode_step(p, c, t, pos, cfg),
            init_cache=lambda b, m: X.init_cache(cfg, b, m),
            train_batch_spec=_lm_specs(cfg),
            subquadratic=True)

    if cfg.family == "encdec":
        from repro.models import encdec as E

        def spec(micro, seq):
            s_src = int(seq * cfg.src_frac)
            return {"embeds": jax.ShapeDtypeStruct((micro, s_src, cfg.d_model),
                                                   jnp.float32),
                    "tokens": jax.ShapeDtypeStruct((micro, seq - s_src),
                                                   jnp.int32)}

        return ModelBundle(
            cfg=cfg,
            init=lambda key: E.init_params(key, cfg),
            loss_fn=lambda p, b: E.loss_fn(p, b, cfg),
            decode_step=lambda p, c, t, pos: E.decode_step(p, c, t, pos, cfg),
            init_cache=lambda b, m: E.init_cache(cfg, b, m, src_len=2048),
            train_batch_spec=spec,
            subquadratic=False)

    if cfg.family == "vlm":
        from repro.models import transformer as T

        def vlm_loss(p, b):
            img = b["img_embeds"].astype(cfg.dtype)        # (B, P, D)
            txt = p["embed"][b["tokens"]]                  # (B, S-P, D)
            embeds = jnp.concatenate([img, txt], axis=1)
            B, P = img.shape[0], img.shape[1]
            S = embeds.shape[1]
            mask = jnp.concatenate(
                [jnp.zeros((B, P), jnp.float32), jnp.ones((B, S - P), jnp.float32)],
                axis=1)
            # tokens for the image prefix are a pad id (0): loss-masked out
            full_tokens = jnp.concatenate(
                [jnp.zeros((B, P), jnp.int32), b["tokens"]], axis=1)
            return T.loss_fn(p, {"tokens": full_tokens, "embeds": embeds,
                                 "loss_mask": mask}, cfg)

        def spec(micro, seq):
            P = cfg.n_img_tokens
            return {"img_embeds": jax.ShapeDtypeStruct((micro, P, cfg.d_model),
                                                       jnp.float32),
                    "tokens": jax.ShapeDtypeStruct((micro, seq - P), jnp.int32)}

        return ModelBundle(
            cfg=cfg,
            init=lambda key: T.init_params(key, cfg),
            loss_fn=vlm_loss,
            decode_step=lambda p, c, t, pos: T.decode_step(p, c, t, pos, cfg),
            init_cache=lambda b, m: T.init_cache(cfg, b, m),
            train_batch_spec=spec,
            subquadratic=False)

    raise ValueError(f"unknown family {cfg.family!r}")
