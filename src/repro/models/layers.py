"""Shared layers: RMSNorm, RoPE, GQA attention (full / sliding-window,
train + KV-cache decode), SwiGLU MLP, sort-free capacity MoE.

All layer parameter trees are built *stacked over depth* (leading dim L) so
model forwards are a single ``lax.scan`` over layers — compile time and HLO
size independent of depth (essential for the 40-cell dry-run).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.ad_checkpoint
import jax.numpy as jnp

from repro.launch import hints


def _init(key, shape, scale=None, dtype=jnp.float32):
    scale = scale if scale is not None else 1.0 / (shape[-2] ** 0.5 if len(shape) >= 2 else 1.0)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms / rope
# ---------------------------------------------------------------------------

def rms_norm(x, gamma, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma


def rope_freqs(d_head: int, theta: float = 1e4):
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, jnp.float32) / d_head))


def apply_rope(x, positions, theta: float = 1e4):
    """x: (..., S, H, hd); positions: (..., S) int32."""
    freqs = rope_freqs(x.shape[-1], theta)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnCfg:
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    qkv_bias: bool = False
    sliding_window: int = 0   # 0 => full causal
    rope_theta: float = 1e4
    q_chunk: int = 512        # query-chunked softmax (VMEM-friendly)
    causal: bool = True       # False => bidirectional (encoders)


def attn_init(key, cfg: AttnCfg, n_layers: int, dtype):
    ks = jax.random.split(key, 4)
    D, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    p = {
        "wq": _init(ks[0], (n_layers, D, H * hd), dtype=dtype),
        "wk": _init(ks[1], (n_layers, D, K * hd), dtype=dtype),
        "wv": _init(ks[2], (n_layers, D, K * hd), dtype=dtype),
        "wo": _init(ks[3], (n_layers, H * hd, D), dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((n_layers, H * hd), dtype)
        p["bk"] = jnp.zeros((n_layers, K * hd), dtype)
        p["bv"] = jnp.zeros((n_layers, K * hd), dtype)
    return p


def _qkv(x, lp, cfg: AttnCfg, positions):
    B, S, _ = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = x @ lp["wq"]
    k = x @ lp["wk"]
    v = x @ lp["wv"]
    if cfg.qkv_bias:
        q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, K, hd)
    v = v.reshape(B, S, K, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if S > 1:
        # sequence-parallel attention: queries stay seq-sharded, the (small,
        # GQA) keys/values are gathered along seq — scores + AV then local.
        # The optimization barrier keeps the gather on the bf16 value (XLA
        # otherwise fuses the fp32 upcast for the scores matmul *before* the
        # all-gather: 2x wire bytes, measured).
        q = hints.seq_shard(q, 1)
        k, v = hints.opt_barrier(
            (hints.gather_seq(k), hints.gather_seq(v)))
        # name the gathered K/V so the layer remat policy can SAVE them:
        # re-gathering on the remat pass costs a third of the attention
        # collective traffic for 134 MB/layer of residency (granite-moe).
        k = jax.ad_checkpoint.checkpoint_name(k, "kv_gathered")
        v = jax.ad_checkpoint.checkpoint_name(v, "kv_gathered")
    return q, k, v


def _sdpa_chunk(q_chunk, k, v, q_pos, k_pos, cfg: AttnCfg):
    """softmax(q k^T) v for one query chunk against full K/V.

    q_chunk: (B, c, H, hd); k/v: (B, S, K, hd). GQA: repeat kv groups.
    """
    B, c, H, hd = q_chunk.shape
    S, K = k.shape[1], k.shape[2]
    rep = H // K
    # grouped-GQA einsum instead of jnp.repeat: keeps the K(=kv) head dim
    # explicit so backward reduces dK/dV at kv-head width (7x smaller
    # all-reduce under sequence sharding; EXPERIMENTS.md §Perf iteration 4).
    q5 = q_chunk.reshape(B, c, K, rep, hd)
    scores = jnp.einsum("bcgrd,bsgd->bgrcs", q5, k,
                        preferred_element_type=jnp.float32) / (hd ** 0.5)
    if cfg.causal:
        mask = q_pos[:, None] >= k_pos[None, :]                   # (c, S)
        if cfg.sliding_window > 0:
            mask &= (q_pos[:, None] - k_pos[None, :]) < cfg.sliding_window
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q_chunk.dtype)
    out = jnp.einsum("bgrcs,bsgd->bcgrd", probs, v)
    return out.reshape(B, c, H, hd)


def _flash_kv_attention(q, k, v, positions, cfg: AttnCfg, kv_chunk: int):
    """Flash-style attention chunked over the KEY/VALUE axis with online
    softmax.  Why KV-chunked (not Q-chunked): under sequence sharding the
    Q/seq dim is distributed — reshaping it into chunks forces GSPMD to
    all-gather full activations per layer (measured, EXPERIMENTS.md §Perf).
    K/V are explicitly replicated (gather_seq in _qkv — small under GQA), so
    chunking THEM is sharding-transparent, and peak scores memory drops from
    (B,H,S,S) to (B,H,S,kc).
    """
    B, S, H, hd = q.shape
    K = k.shape[2]
    rep = H // K
    kc = min(kv_chunk, S)
    if S % kc != 0:
        kc = S
    nc = S // kc
    q5 = q.reshape(B, S, K, rep, hd)
    kt = k.reshape(B, nc, kc, K, hd).swapaxes(0, 1)
    vt = v.reshape(B, nc, kc, K, hd).swapaxes(0, 1)
    pos_t = positions.reshape(nc, kc)

    def body(carry, xs):
        m_prev, l_prev, acc = carry
        k_c, v_c, kp = xs
        s = jnp.einsum("bsgrd,btgd->bgrst", q5, k_c,
                       preferred_element_type=jnp.float32) / (hd ** 0.5)
        if cfg.causal:
            mask = positions[:, None] >= kp[None, :]
            if cfg.sliding_window > 0:
                mask &= (positions[:, None] - kp[None, :]) < cfg.sliding_window
            s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        scale = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_prev * scale + jnp.sum(p, axis=-1)
        acc = acc * scale[..., None] + jnp.einsum(
            "bgrst,btgd->bgrsd", p.astype(v_c.dtype), v_c,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc), ()

    m0 = jnp.full((B, K, rep, S), -1e30, jnp.float32)
    l0 = jnp.zeros((B, K, rep, S), jnp.float32)
    acc0 = jnp.zeros((B, K, rep, S, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (kt, vt, pos_t))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    # (B, K, rep, S, hd) -> (B, S, H, hd)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H * hd).astype(q.dtype)


def attention(x, lp, cfg: AttnCfg, positions):
    """Training attention. x: (B, S, D) -> (B, S, D).

    Single-block SDPA for small S (tests / reduced configs); flash-style
    KV-chunked online softmax for long sequences.
    """
    B, S, D = x.shape
    q, k, v = _qkv(x, lp, cfg, positions)
    if S <= cfg.q_chunk:
        y = _sdpa_chunk(q, k, v, positions, positions, cfg)
        y = y.reshape(B, S, cfg.n_heads * cfg.d_head)
    else:
        y = _flash_kv_attention(q, k, v, positions, cfg, cfg.q_chunk)
    return y @ lp["wo"]


def attention_decode(x, lp, cfg: AttnCfg, cache_k, cache_v, position):
    """One-token decode with a pre-filled KV cache.

    x: (B, 1, D); cache_k/v: (B, S_cache, K, hd); position: scalar int32 index
    where the new token's K/V is written.  Returns (y, new_k, new_v).
    """
    B = x.shape[0]
    pos_arr = jnp.full((B, 1), position, jnp.int32)
    q, k_new, v_new = _qkv(x, lp, cfg, pos_arr)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k_new.astype(cache_k.dtype), position, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v_new.astype(cache_v.dtype), position, axis=1)
    S = cache_k.shape[1]
    k_pos = jnp.arange(S, dtype=jnp.int32)
    q_pos = jnp.full((1,), position, jnp.int32)
    valid = k_pos <= position
    if cfg.sliding_window > 0:
        valid &= (position - k_pos) < cfg.sliding_window
    K, rep = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    q5 = q.reshape(B, 1, K, rep, cfg.d_head)
    scores = jnp.einsum("bcgrd,bsgd->bgrcs", q5, cache_k,
                        preferred_element_type=jnp.float32) / (cfg.d_head ** 0.5)
    scores = jnp.where(valid[None, None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    y = jnp.einsum("bgrcs,bsgd->bcgrd", probs, cache_v).reshape(B, 1, -1)
    return y @ lp["wo"], cache_k, cache_v


# ---------------------------------------------------------------------------
# MLP / MoE
# ---------------------------------------------------------------------------

def mlp_init(key, d_model, d_ff, n_layers, dtype):
    ks = jax.random.split(key, 3)
    return {"w1": _init(ks[0], (n_layers, d_model, d_ff), dtype=dtype),
            "w3": _init(ks[1], (n_layers, d_model, d_ff), dtype=dtype),
            "w2": _init(ks[2], (n_layers, d_ff, d_model), dtype=dtype)}


def swiglu(x, lp):
    return (jax.nn.silu(x @ lp["w1"]) * (x @ lp["w3"])) @ lp["w2"]


def chunked_ce(x, head, targets, mask=None, chunk: int = 512):
    """Sequence-chunked cross entropy: never materializes (B, S, V) logits.

    x: (B, S, D) final hidden (caller drops the last position);
    head: (D, V); targets: (B, S) int32; mask: (B, S) float or None.
    The per-chunk body is rematerialized, so backward also stays at
    (B, chunk, V) peak.
    """
    B, S, D = x.shape
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    c = min(chunk, S)
    pad = (-S) % c
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nc = (S + pad) // c
    xc = x.reshape(B, nc, c, D).swapaxes(0, 1)
    tc = targets.reshape(B, nc, c).swapaxes(0, 1)
    mc = mask.reshape(B, nc, c).swapaxes(0, 1)

    @partial(jax.checkpoint, prevent_cse=False)
    def body(carry, xs):
        xb, tb, mb = xs
        logits = (xb @ head).astype(jnp.float32)
        # one-hot contraction instead of take_along_axis: the reduction over
        # the (vocab-sharded) axis stays local + a tiny all-reduce, instead of
        # an all-gather of the full (B, chunk, V) logits.
        lse = jax.nn.logsumexp(logits, axis=-1)
        oh = jax.nn.one_hot(tb, logits.shape[-1], dtype=logits.dtype)
        tgt = jnp.einsum("bcv,bcv->bc", logits, oh)
        nll = lse - tgt
        return (carry[0] + jnp.sum(nll * mb), carry[1] + jnp.sum(mb)), ()

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                 (xc, tc, mc))
    return tot / jnp.maximum(cnt, 1.0)


def moe_init(key, d_model, d_ff, n_experts, n_layers, dtype):
    ks = jax.random.split(key, 4)
    return {"router": _init(ks[0], (n_layers, d_model, n_experts), dtype=jnp.float32),
            "w1": _init(ks[1], (n_layers, n_experts, d_model, d_ff), dtype=dtype),
            "w3": _init(ks[2], (n_layers, n_experts, d_model, d_ff), dtype=dtype),
            "w2": _init(ks[3], (n_layers, n_experts, d_ff, d_model), dtype=dtype)}


def _topk_iterative(scores, k: int):
    """top-k via k argmax+mask rounds. jax.lax.top_k over a sharded batch
    lowers through Shardy's replicate-fallback (measured 6.4 GB/dev of
    all-gather on granite-moe); k reduces stay fully local."""
    vals, idxs = [], []
    s = scores
    for _ in range(k):
        i = jnp.argmax(s, axis=-1)
        v = jnp.max(s, axis=-1)
        vals.append(v)
        idxs.append(i)
        s = s - jax.nn.one_hot(i, scores.shape[-1], dtype=s.dtype) * 1e9
    return jnp.stack(vals, axis=-1), jnp.stack(idxs, axis=-1)


def moe_apply(x, lp, n_experts: int, top_k: int, capacity_factor: float = 1.25,
              ep: bool = False):
    """Capacity-based top-k MoE with SHARD-LOCAL dispatch.

    Distribution design (EXPERIMENTS.md §Perf, granite-moe iterations): a
    flat (B*S) dispatch mixes the sequence-sharded dim into an unsharded one,
    so every scatter/gather against the expert buffer lowers to an all-reduce
    of the full fp32 buffer (measured 103 GB/dev per round on granite-moe).
    Instead the sequence dim is split explicitly into
    (n_shards, S_local) — a sharding-preserving reshape — and dispatch /
    combine are vmapped per shard: all index ops stay device-local.

    * ep=False (replicated experts — right call for fine-grained MoE like
      granite's 32 x d_ff=512): expert weights are FSDP-gathered per layer
      (~100 MB) and compute is fully local. Capacity is per shard.
    * ep=True (big experts — llama4/jamba): the dispatch buffer is resharded
      shard-dim->expert-dim (an all-to-all), expert matmuls run
      expert-parallel over `model`, and the result is resharded back.
    """
    from repro.launch import hints as H
    B, S, D = x.shape
    E, k = n_experts, top_k
    ns = H.seq_shard_count()
    if S % ns != 0 or (S // ns) * k < E:
        ns = 1
    S_loc = S // ns
    C = max(1, int(S_loc * k / E * capacity_factor))

    xg = hints.shard_dim(x.reshape(B, ns, S_loc, D), 1)      # dim1: seq-sharded
    logits = xg.astype(jnp.float32) @ lp["router"]           # (B, ns, S_loc, E)
    gate_all = hints.shard_dim(jax.nn.softmax(logits, axis=-1), 1)
    gates, idx = _topk_iterative(gate_all, k)                # (B, ns, S_loc, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    flat_e = idx.reshape(B, ns, S_loc * k)
    oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)          # (B, ns, S*k, E)
    pos = jnp.cumsum(oh, axis=2) - oh
    pos = jnp.sum(pos * oh, axis=-1)                         # (B, ns, S*k)
    keep = pos < C
    e_idx = jnp.where(keep, flat_e, E - 1)
    p_idx = jnp.where(keep, pos, C - 1)
    # The slot->cell map is INJECTIVE on kept slots, so scatter and gather
    # are exact transposes of each other. XLA cannot know this: the autodiff
    # transpose of the batched gather lowers to a replicate-then-scatter
    # (measured 2x51 GB/dev in backward+remat). custom_vjp encodes the
    # injectivity — dispatch^T = collect, collect^T = dispatch — so both
    # directions are shard-local pinned gathers/scatters.

    def _scatter(vals, el, pl):
        f = lambda v, e, p: jnp.zeros((E, C, D), v.dtype).at[e, p].add(v)
        return hints.shard_dim(jax.vmap(jax.vmap(f))(vals, el, pl), 1)

    def _collect(buf, el, pl):
        f = lambda b1, e, p: b1[e, p]
        return hints.shard_dim(jax.vmap(jax.vmap(f))(buf, el, pl), 1)

    @jax.custom_vjp
    def moe_dispatch(vals, el, pl):
        return _scatter(vals, el, pl)

    moe_dispatch.defvjp(
        lambda vals, el, pl: (_scatter(vals, el, pl), (el, pl)),
        lambda res, d_buf: (_collect(d_buf, *res), None, None))

    @jax.custom_vjp
    def moe_collect(buf, el, pl):
        return _collect(buf, el, pl)

    moe_collect.defvjp(
        lambda buf, el, pl: (_collect(buf, el, pl), (el, pl)),
        lambda res, d_out: (_scatter(d_out, *res), None, None))

    vals = jnp.broadcast_to(xg[:, :, :, None, :],
                            (B, ns, S_loc, k, D)).reshape(B, ns, S_loc * k, D)
    vals = jnp.where(keep[..., None], vals, 0).astype(x.dtype)

    if ep:
        # ep mode (big experts, batch+seq both sharded): Shardy's batched
        # scatter/gather replicate-fallback costs TB/dev here (measured on
        # jamba). Dispatch/combine as ONE-HOT EINSUMS instead — partitions
        # perfectly, and at d_ff >= 8k the extra (E*C)/(3*d_ff) ~ 1% FLOPs
        # is noise.
        cell = jnp.where(keep, e_idx * C + p_idx, E * C)
        oh = jax.nn.one_hot(cell, E * C, dtype=x.dtype)  # (B,ns,S*k,EC)
        buf = jnp.einsum("bnsk,bnsd->bnkd", oh, vals)
        # Two-step reshard (measured best of three variants on jamba:
        # 3.08 TB vs 3.51 TB direct-to-expert vs 7.59 TB ns-only): pin the
        # einsum output seq-sharded first, THEN all-to-all to
        # expert-parallel — GSPMD lowers the staged transition efficiently.
        buf = hints.shard_dim(buf.reshape(B, ns, E, C, D), 1)
        buf = hints.shard_dim(buf, 2, ("model",))
    else:
        buf = moe_dispatch(vals, e_idx, p_idx)   # (B,ns,E,C,D), ns-sharded

    if ep:
        # JIT-gather the non-expert ('data') shards of the expert weights in
        # bf16, keeping E expert-parallel: avoids the f32 full-weight gather
        # GSPMD falls back to when the stored 'data' sharding on d_ff
        # conflicts with the batch dim of buf (measured 515 GB/dev, llama4).
        def _egather(w):
            mesh = hints._CTX["mesh"]
            if mesh is None:
                return w
            from jax.sharding import NamedSharding, PartitionSpec as P
            return hints.opt_barrier(
                jax.lax.with_sharding_constraint(
                    w, NamedSharding(mesh, P("model", None, None))))

        w1, w2, w3 = _egather(lp["w1"]), _egather(lp["w2"]), _egather(lp["w3"])
    else:
        from repro.launch.hints import fsdp_params
        g = fsdp_params({"g1": lp["w1"], "g2": lp["w2"], "g3": lp["w3"]},
                        skip=())
        w1, w2, w3 = g["g1"], g["g2"], g["g3"]

    h = jnp.einsum("bnecd,edf->bnecf", buf, w1)
    g3 = jnp.einsum("bnecd,edf->bnecf", buf, w3)
    y = jnp.einsum("bnecf,efd->bnecd", jax.nn.silu(h) * g3, w2)

    if ep:
        y = H.shard_dim(y, 1)                                # all-to-all out
        out_slots = jnp.einsum("bnsk,bnkd->bnsd", oh,
                               y.reshape(B, ns, E * C, D).astype(x.dtype))
        out_slots = hints.shard_dim(out_slots, 1)
    else:
        out_slots = moe_collect(y.astype(x.dtype), e_idx, p_idx)
    gl = gates.reshape(B, ns, S_loc * k)
    out_slots = jnp.where(keep[..., None], out_slots, 0) \
        * gl[..., None].astype(x.dtype)
    out = hints.shard_dim(
        out_slots.reshape(B, ns, S_loc, k, D).sum(axis=3), 1)
    frac = jnp.mean(jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32),
                    axis=(0, 1, 2))
    prob = jnp.mean(gate_all, axis=(0, 1, 2))
    aux = E * jnp.sum(frac * prob)
    return out.reshape(B, S, D), aux
