"""Async straggler-tolerant rounds under heavy-tail client latency.

    PYTHONPATH=src python examples/async_stragglers.py

A least-squares cohort where client wall-clock latency is lognormal
heavy-tail: the sync round waits for the slowest straggler every round,
while the async driver (``round_mode="async(deadline=p90,...)"``) closes
at the p90 deadline, folding the slow tail back in one or two rounds
later at the buffered-staleness weight. The script checks the two claims
the round-latency benchmark rows quantify:

  * wall-clock: the simulated async close time sits far below the sync
    barrier at the tail percentiles (here the barrier pays the slowest of
    64 lognormal draws, the async round pays the fixed p90 deadline);
  * convergence: delaying + down-weighting the tail costs little — the
    async run's final loss lands within a small factor of the sync run's.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compression, fedavg
from repro.core.context import RoundContext, RoundModePolicy
from repro.fed.async_server import parse_latency, simulate_close_times

N, D, ROUNDS = 64, 256, 30
LATENCY = "lognormal(median=1.0,sigma=1.0,seed=7)"

# deadline = the latency model's p90: the round closes when ~90% of the
# cohort has reported; the slow tail folds late via poly staleness
_model = parse_latency(LATENCY)
_draws = np.concatenate([_model.sample(r, N) for r in range(ROUNDS)])
DEADLINE = round(float(np.percentile(_draws[np.isfinite(_draws)], 90)), 3)


def run(round_mode, latency):
    comp = compression.Pipeline("ef|zsign")
    cfg = fedavg.FedConfig(n_clients=N, client_lr=0.05, server_lr=0.5)
    ctx = RoundContext(cohort="stream(shard=16,feed=host)",
                       round_mode=round_mode, latency=latency)
    loss_fn = lambda p, b: 0.5 * jnp.mean((p["x"] - b["y"]) ** 2)
    step = fedavg.build_round_step(loss_fn, comp, cfg, ctx)
    target = jax.random.normal(jax.random.PRNGKey(3), (D,))
    y = jnp.broadcast_to(target, (1, N, 1, D)) + 0.1 * jax.random.normal(
        jax.random.PRNGKey(4), (1, N, 1, D))
    st = fedavg.init_server_state({"x": jnp.zeros(D)}, cfg, comp,
                                  jax.random.PRNGKey(1))
    mask = jnp.ones((1, N))
    for _ in range(ROUNDS):
        st, m = step(st, {"y": y}, mask)
    return float(m.loss), float(m.participation)


ASYNC = f"async(deadline={DEADLINE},min_clients=8,staleness=poly(0.5))"
policy = RoundModePolicy.parse(ASYNC)
t0 = time.time()
sync_loss, sync_part = run("sync", "zero")
async_loss, async_part = run(ASYNC, LATENCY)
dt = time.time() - t0

closes = simulate_close_times(policy, _model, ROUNDS, N)
p50a, p90a = np.percentile(closes[:, 0], [50, 90])
p50s, p90s = np.percentile(closes[:, 1], [50, 90])

print(f"cohort n={N} d={D} rounds={ROUNDS} latency={LATENCY}")
print(f"deadline=p90={DEADLINE}  ({dt:.1f}s for both runs on CPU)")
print(f"round close time: async p50={p50a:.2f} p90={p90a:.2f} | "
      f"sync barrier p50={p50s:.2f} p90={p90s:.2f}")
print(f"final loss: sync={sync_loss:.5f} async={async_loss:.5f} | "
      f"last-round participation: sync={sync_part:.1f} "
      f"async={async_part:.1f}")

# the deadline must beat the straggler barrier at the tail...
assert p90a < 0.5 * p90s, (p90a, p90s)
# ...without giving up convergence: within a small factor of sync
assert async_loss < 3.0 * sync_loss + 1e-3, (async_loss, sync_loss)
assert async_part > 0.5 * N
print("OK")
