"""Non-i.i.d. federated classification (paper §4.2 setting).

    PYTHONPATH=src python examples/noniid_classification.py

Each of 10 clients holds ONE class's data (extreme heterogeneity). Compares
uncompressed SGD+momentum, vanilla SignSGD (diverges), EF-SignSGD and the
paper's 1-SignSGD, with partial participation + simulated stragglers.
"""
import jax
import jax.numpy as jnp

from repro.models.mlp import mlp_loss_builder
from repro.core import compression, fedavg
from repro.core.noise import eta_z
from repro.data import synthetic
from repro.fed.sampling import ParticipationSampler

N, ROUNDS = 10, 200
x, y = synthetic.gaussian_mixture_task(n_classes=10, dim=64, n_per_class=200)
parts = synthetic.label_partition(y, N)
init, loss_fn, acc_fn = mlp_loss_builder(64, 10)
sampler = ParticipationSampler(total_clients=N, per_round=8,
                               over_provision=1.25, failure_rate=0.05)

for name, spec, slr in [
        ("SGD+momentum (32 bit)", "identity", 0.05),
        ("vanilla SignSGD", "zsign", 0.2),          # sigma defaults to 0
        ("EF-SignSGD", "ef|zsign", 1.0),            # EF composes as a stage
        ("1-SignSGD (paper)", "zsign(z=1,sigma=0.05)",
         0.01 / (eta_z(1) * 0.05 * 0.05)),
]:
    comp = compression.Pipeline(spec)
    opt = ("momentum", (("beta", 0.9),)) if spec in ("identity", "ef|zsign") \
        else ("sgd", ())
    cfg = fedavg.FedConfig(n_clients=N, client_lr=0.05, server_lr=slr,
                           server_opt=opt[0], server_opt_kw=opt[1])
    step = jax.jit(fedavg.build_round_step(loss_fn, comp, cfg))
    state = fedavg.init_server_state(init(jax.random.PRNGKey(0)), cfg, comp,
                                     jax.random.PRNGKey(1))
    bits = 0.0
    for t in range(ROUNDS):
        batch = synthetic.client_batches(x, y, parts, (1, N, 1, 32),
                                         seed=1, round_idx=t)
        mask = jnp.asarray(sampler.mask((1, N)))
        state, m = step(state, batch, mask)
        bits += float(m.uplink_bits)
    acc = acc_fn(state.params, x, y)
    wf = comp.wire_format()
    print(f"{name:24s} acc={acc:.3f}  uplink={bits/1e6:8.2f} Mbit "
          f"({32.0/wf.bits_per_coord:4.0f}x compression, "
          f"{wf.layout}/{wf.dtype} wire)")
