"""Quickstart: the paper's §4.1 consensus problem in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Shows the headline result: vanilla SignSGD stalls under heterogeneous
gradients; z-SignSGD (the paper's stochastic sign) converges; uplink is 1
bit/coordinate either way. Compressors are built from pipeline spec strings
(core/compression.py — ``Pipeline("zsign(z=1,sigma=2.0)")``; stages compose
with ``|``, e.g. ``"ef|topk(frac=0.01)"`` — see docs/API.md).
"""
import jax
import jax.numpy as jnp

from repro.core import compression, fedavg

D, N, ROUNDS = 200, 10, 2000

key = jax.random.PRNGKey(0)
targets = jax.random.normal(key, (1, N, D))           # y_i per client
optimum = targets[0].mean(0)
loss_fn = lambda p, b: 0.5 * jnp.sum((p["x"] - b["y"]) ** 2)
batch = {"y": targets[:, :, None]}                    # (groups, N, E, D)
mask = jnp.ones((1, N))

print(f"consensus problem: d={D}, {N} clients  "
      f"(optimum = mean of client targets)")
for name, spec, slr in [
        ("uncompressed GD", "identity", 1.0),
        ("vanilla SignSGD", "zsign", 0.05),       # sigma defaults to 0
        ("1-SignSGD  (z=1, Gaussian)", "zsign(z=1,sigma=2.0)", 2.0),
        ("inf-SignSGD (z=inf, uniform)", "zsign(z=inf,sigma=2.0)", 2.5),
]:
    comp = compression.Pipeline(spec)
    cfg = fedavg.FedConfig(n_clients=N, client_lr=0.01, server_lr=slr)
    step = jax.jit(fedavg.build_round_step(loss_fn, comp, cfg))
    state = fedavg.init_server_state({"x": jnp.zeros(D)}, cfg, comp,
                                     jax.random.PRNGKey(1))
    for _ in range(ROUNDS):
        state, m = step(state, batch, mask)
    dist = float(jnp.linalg.norm(state.params["x"] - optimum))
    wf = comp.wire_format()
    print(f"  {name:30s} dist-to-opt={dist:8.4f}   "
          f"uplink={float(m.uplink_bits)/1e3:7.1f} kbit/round "
          f"[{wf.layout}/{wf.dtype}]")
