"""DP-SignFedAvg (paper Algorithm 2 / Appendix F): client-level DP with
1-bit uplink, as ONE pipeline spec.

    PYTHONPATH=src python examples/dp_federated.py

Calibrates the Gaussian noise multiplier to a target (eps, delta) via the
RDP accountant, then trains with the ``dp`` transform stage composed over
the packed sign codec:

    dp(clip=C, noise=nm*C) | zsign(z=1)

The pipeline FUSES the dp noise into the sign codec's sigma (see
compression.DPTransform), so the same Gaussian does double duty — privacy
AND the sign-bias correction of the paper's Lemma 1 — while the wire stays
bitpacked at 1 bit/coord and the dense per-client noise buffer never exists
(the counter-based fused encoder samples each wire bit from its exact
Bernoulli law).
"""
import jax
import jax.numpy as jnp

from repro.models.mlp import mlp_loss_builder
from repro.core import compression, fedavg
from repro.core.dp import calibrate_noise, compute_epsilon
from repro.core.noise import eta_z
from repro.data import synthetic

ROUNDS, N, CLIP, DELTA = 200, 50, 0.5, 1e-3
Q = 0.3        # client subsampling ratio (privacy amplification, paper App. F)
x, y = synthetic.gaussian_mixture_task(n_classes=10, dim=64, n_per_class=200)
parts = synthetic.dirichlet_partition(y, min(N, 10), alpha=1.0)
init, loss_fn, acc_fn = mlp_loss_builder(64, 10)

for target_eps in [2.0, 8.0]:
    nm = calibrate_noise(q=Q, steps=ROUNDS, target_eps=target_eps,
                         delta=DELTA)
    sigma = nm * CLIP
    comp = compression.Pipeline(f"dp(clip={CLIP},noise={sigma})|zsign(z=1)")
    assert comp.wire_bits_per_coord == 1.0          # DP rides the 1-bit wire
    assert comp.codec.sigma == sigma                # noise fused into sigma
    cfg = fedavg.FedConfig(n_clients=N, client_lr=0.05,
                           server_lr=0.005 / (eta_z(1) * sigma * 0.05),
                           server_opt="momentum",
                           server_opt_kw=(("beta", 0.9),))
    step = jax.jit(fedavg.build_round_step(loss_fn, comp, cfg))
    state = fedavg.init_server_state(init(jax.random.PRNGKey(0)), cfg, comp,
                                     jax.random.PRNGKey(1))
    import numpy as _np
    rng = _np.random.RandomState(0)
    for t in range(ROUNDS):
        batch = synthetic.client_batches(x, y, parts, (1, N, 1, 32),
                                         seed=3, round_idx=t)
        mask = _np.zeros(N, _np.float32)
        mask[rng.choice(N, max(1, int(Q * N)), replace=False)] = 1.0
        state, m = step(state, batch, jnp.asarray(mask)[None])
    eps = compute_epsilon(q=Q, noise_multiplier=nm, steps=ROUNDS,
                          delta=DELTA)
    wf = comp.wire_format()
    print(f"target eps={target_eps:4.1f}: noise multiplier={nm:5.2f} "
          f"(achieved eps={eps:5.2f}, delta={DELTA})  "
          f"acc={acc_fn(state.params, x, y):.3f}  "
          f"[{wf.bits_per_coord:g} bit/coord {wf.layout} uplink]")
