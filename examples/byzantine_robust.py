"""Byzantine-robust compressed aggregation under a live wire attack.

    PYTHONPATH=src python examples/byzantine_robust.py [--adversary SPEC]
                                                       [--agg MODE] [--all]

n=16 clients solve the consensus problem while f=6 of them (f < n/2)
sign-flip every payload on the wire (``fed/adversary.py``). All robust
``agg=`` modes stay in the compressed domain — majority vote, trimmed(f)
mean and coordinate-wise median are closed-form post-processings of the
carried int32 (signed_count, n_live) vote pair, so the round costs the same
single reduce as the mean path (docs/API.md).

Headline: ``agg=vote`` converges at full speed — each coordinate still
steps a whole unit in the honest majority's direction — while ``agg=mean``
is demonstrably degraded: the flipped votes collapse its step magnitude to
(n - 2f)/n = 1/4, leaving it far from the optimum at the same round budget.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core import compression, fedavg

N, D, F, ROUNDS = 16, 128, 6, 60


def run(agg: str, adversary: str, rounds: int = ROUNDS):
    key = jax.random.PRNGKey(0)
    targets = 5.0 + jax.random.normal(key, (1, N, D))
    honest_opt = targets[0, F:].mean(0)
    loss_fn = lambda p, b: 0.5 * jnp.sum((p["x"] - b["y"]) ** 2)
    batch = {"y": targets[:, :, None]}
    mask = jnp.ones((1, N))
    comp = compression.Pipeline(f"zsign_packed(agg={agg})")
    # effective sign step = server_lr * client_lr = 0.1 per coordinate
    cfg = fedavg.FedConfig(n_clients=N, client_lr=0.05, server_lr=2.0)
    ctx = fedavg.RoundContext(weights_are_mask=True, adversary=adversary)
    step = jax.jit(fedavg.build_round_step(loss_fn, comp, cfg, ctx))
    state = fedavg.init_server_state({"x": jnp.zeros(D)}, cfg, comp,
                                     jax.random.PRNGKey(1))
    for _ in range(rounds):
        state, m = step(state, batch, mask)
    dist = float(jnp.linalg.norm(state.params["x"] - honest_opt))
    return dist, float(jnp.linalg.norm(honest_opt)), m


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--adversary", default=f"sign_flip(f={F})",
                    help="attack spec (fed/adversary.py grammar); e.g. "
                         f"'byte_corrupt(f={F},p=0.2)', 'collude(f={F})', "
                         f"'dropout(f={F})'")
    ap.add_argument("--agg", default=None,
                    help="run one agg mode (mean|vote|trimmed(f=..)|median) "
                         "instead of the vote-vs-mean comparison")
    ap.add_argument("--all", action="store_true",
                    help="sweep every agg mode under the attack")
    ap.add_argument("--rounds", type=int, default=ROUNDS)
    args = ap.parse_args()

    modes = ([args.agg] if args.agg else
             ["mean", "vote", "trimmed(f=6)", "median"] if args.all else
             ["mean", "vote"])
    print(f"consensus: d={D}, n={N} clients, adversary={args.adversary}, "
          f"{args.rounds} rounds")
    dists = {}
    for agg in modes:
        dist, d0, m = run(agg, args.adversary, args.rounds)
        dists[agg] = dist
        print(f"  agg={agg:14s} dist-to-honest-opt={dist:8.3f}  "
              f"(init was {d0:.1f})  uplink="
              f"{float(m.uplink_bits) / 1e3:.1f} kbit/round")
    if "vote" in dists and "mean" in dists:
        verdict = ("vote converged, mean degraded"
                   if dists["vote"] < 0.5 * dists["mean"]
                   else "no separation (attack below robustness threshold?)")
        print(f"  -> {verdict}")


if __name__ == "__main__":
    main()
