"""Compressed SCAFFOLD under Dirichlet label skew (the cv stage).

    PYTHONPATH=src python examples/scaffold_heterogeneous.py

20 clients, dirichlet_partition(alpha=0.1) — each client's label histogram
is dominated by a couple of classes, so local pseudo-gradients point in
systematically different directions and plain sign compression drifts
(client drift, the SCAFFOLD problem). ``cv|zsign_packed`` keeps a per-client
control variate c_i and a shared server variate c, corrects each update
PRE-codec (q_i = p_i - eta * (c_i - c)), and updates both variates from the
locally-decoded payload — the uplink stays EXACTLY 1 bit/coord, same as
plain zsign_packed. At equal rounds the corrected run must reach a lower
final loss.
"""
import jax
import jax.numpy as jnp

from repro.models.mlp import mlp_loss_builder
from repro.core import compression, fedavg
from repro.data import synthetic

N, ROUNDS, ALPHA = 20, 150, 0.1
x, y = synthetic.gaussian_mixture_task(n_classes=10, dim=64, n_per_class=200)
parts = synthetic.dirichlet_partition(y, N, alpha=ALPHA, seed=0)
init, loss_fn, acc_fn = mlp_loss_builder(64, 10)

results = {}
for name, spec in [
        ("zsign_packed (plain)", "zsign_packed(z=1,sigma=0.05)"),
        ("cv|zsign_packed (SCAFFOLD)",
         "cv(eta=0.5,beta=0.5)|zsign_packed(z=1,sigma=0.05)"),
]:
    comp = compression.Pipeline(spec)
    cfg = fedavg.FedConfig(n_clients=N, client_lr=0.05, server_lr=0.02,
                           local_steps=2)
    step = jax.jit(fedavg.build_round_step(loss_fn, comp, cfg))
    state = fedavg.init_server_state(init(jax.random.PRNGKey(0)), cfg, comp,
                                     jax.random.PRNGKey(1))
    mask = jnp.ones((1, N))
    loss = float("nan")
    for t in range(ROUNDS):
        batch = synthetic.client_batches(x, y, parts, (1, N, 2, 32),
                                         seed=1, round_idx=t)
        state, m = step(state, batch, mask)
        loss = float(m.loss)
    acc = acc_fn(state.params, x, y)
    results[name] = loss
    print(f"{name:28s} final loss={loss:.4f}  acc={acc:.3f}  "
          f"(uplink {comp.wire_format().bits_per_coord:.0f} bit/coord)")

assert results["cv|zsign_packed (SCAFFOLD)"] < results["zsign_packed (plain)"], \
    "control variates must beat plain sign compression under label skew"
print("OK: cv|zsign_packed beats plain zsign_packed at equal rounds "
      f"(alpha={ALPHA} Dirichlet skew)")
