"""End-to-end driver example: federated LM training with z-sign compression,
checkpoint/restart and the Plateau sigma schedule — via the production
launcher (repro.launch.train).

    PYTHONPATH=src python examples/train_lm_federated.py

Equivalent CLI:
    PYTHONPATH=src python -m repro.launch.train --arch qwen2_0_5b --reduced \
        --rounds 60 --clients 4 --local-steps 2 --compressor zsign \
        --plateau --ckpt-dir /tmp/zsign_ckpt
"""
import subprocess
import sys
import tempfile

with tempfile.TemporaryDirectory() as d:
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "qwen2_0_5b", "--reduced",
           "--rounds", "60", "--clients", "4", "--local-steps", "2",
           "--micro-batch", "2", "--seq-len", "64",
           "--compressor", "zsign", "--sigma", "0.01", "--plateau",
           "--server-lr", "8.0",
           "--participation", "1.0", "--over-provision", "1.25",
           "--ckpt-dir", d, "--save-every", "25"]
    print("$", " ".join(cmd))
    subprocess.run(cmd, check=True)
    # simulate a crash + restart: the driver resumes from the checkpoint
    print("\n--- simulated restart (resumes from newest checkpoint) ---")
    cmd[cmd.index("--rounds") + 1] = "80"
    subprocess.run(cmd, check=True)
