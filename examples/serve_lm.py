"""Batched serving example: prefill-free KV-cache decode on a reduced model.

    PYTHONPATH=src python examples/serve_lm.py

Drives the same `decode_step` the dry-run lowers for the decode_32k /
long_500k cells: batched requests, greedy sampling, per-step cache update.
"""
import time

import jax
import jax.numpy as jnp

from repro.configs.common import get_arch
from repro.models.api import build_model

ARCH = "qwen2_0_5b"
BATCH, STEPS, MAX_LEN = 8, 48, 128

arch = get_arch(ARCH).reduced()
bundle = build_model(arch.model)
params = bundle.init(jax.random.PRNGKey(0))
cache = bundle.init_cache(BATCH, MAX_LEN)
step = jax.jit(bundle.decode_step)

tokens = jax.random.randint(jax.random.PRNGKey(1), (BATCH, 1), 0,
                            arch.model.vocab)
out = [tokens]
t0 = time.time()
for pos in range(STEPS):
    logits, cache = step(params, cache, tokens, jnp.int32(pos))
    tokens = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out.append(tokens)
dt = time.time() - t0
seqs = jnp.concatenate(out, axis=1)
print(f"arch={arch.model.name} (reduced) batch={BATCH}")
print(f"decoded {STEPS} steps in {dt:.2f}s "
      f"({BATCH * STEPS / dt:.0f} tok/s on CPU)")
print("sample token ids:", seqs[0, :16].tolist())
assert seqs.shape == (BATCH, STEPS + 1)
assert bool(jnp.all((seqs >= 0) & (seqs < arch.model.vocab)))
print("OK")
